"""Control-plane invariants (serving/controller.py).

The bar matches every other plane in this repo: the controller may only
make decisions an operator could have scripted — so a controller-driven
run replayed as a script on a controller-off engine is bit-identical, the
whole closed loop adds zero new jit traces, scale decisions never flap
under an oscillating load trace, and the deadline-aware victim policy can
never evict interactive work. Plus the rebalance-cooldown regression: a
scale-out must reset the auto-rebalance cooldown so the joiner receives
load immediately."""
import dataclasses

import jax
import numpy as np

from conftest import reduced
from repro.core.orchestrator import Orchestrator
from repro.data.workloads import make_workload
from repro.serving.api import RequestSpec
from repro.serving.engine import EngineConfig, InferenceEngine
from repro.serving.scheduler import ScalePlan, run_serving

PROMPT = np.arange(1, 9, dtype=np.int32)


def make_engine(**kw):
    cfg = reduced("mixtral_8x7b", cap_factor=4.0)
    defaults = dict(max_batch=8, max_seq=64, num_aw=2, num_ew=2)
    defaults.update(kw)
    return InferenceEngine(cfg, EngineConfig(**defaults),
                           jax.random.PRNGKey(0))


def mixed_workload(duration=5.0):
    wl = make_workload("mixed_slo", rate_rps=3.0, duration=duration,
                       seed=7, interactive_deadline=0.3)
    return [dataclasses.replace(w, prompt_len=min(w.prompt_len, 16),
                                max_new_tokens=min(w.max_new_tokens, 8))
            for w in wl]


def traces(eng):
    return eng._decode._cache_size() + eng.decode_plane.segment_traces()


# --------------------------------------------------------------------------
# bit-identity: controller on == its decisions replayed as a script
# --------------------------------------------------------------------------

def test_controller_bit_identical_to_replayed_script():
    """A controller-on run records its decision history; the same
    decisions replayed as ScalePlans + scripted budget changes on a
    controller-off engine produce byte-for-byte the same outputs — the
    controller changes WHEN knobs move, never what any knob does."""
    kw = dict(max_ew=4, chunk_token_budget=32, prefill_token_cap=256)
    wl = mixed_workload()

    eng_on = make_engine(controller="on", **kw)
    orch_on = Orchestrator(eng_on, worker_init_time=0.4,
                           weight_push_time=0.2)
    m_on = run_serving(eng_on, wl, 60.0, orchestrator=orch_on,
                       step_time=0.02, prefill_token_time=0.002)
    decisions = eng_on.controller.decisions
    # non-vacuous: the loop actually closed at least once
    assert any(d["kind"] in ("rebalance", "budget", "scale_out")
               for d in decisions), decisions

    eng_off = make_engine(**kw)
    assert eng_off.controller is None
    # the controller switched the replica packer to weighted mode at
    # construction; the scripted twin must compute identical plans
    eng_off.placement_mgr.split_mode = "weighted"
    kind_map = {"scale_out": "add_ew", "scale_in": "drain_ew",
                "rebalance": "rebalance"}
    scales = [ScalePlan(d["t"], kind_map[d["kind"]], d.get("ew", -1))
              for d in decisions if d["kind"] in kind_map]
    budget_script = sorted((d["t"], d["budget"]) for d in decisions
                           if d["kind"] == "budget")
    orig_step = eng_off.step

    def scripted_step(now=None):
        while budget_script and now is not None and \
                now >= budget_script[0][0]:
            eng_off.chunked.set_budget(budget_script.pop(0)[1])
        return orig_step(now=now)

    eng_off.step = scripted_step
    orch_off = Orchestrator(eng_off, worker_init_time=0.4,
                            weight_push_time=0.2)
    m_off = run_serving(eng_off, wl, 60.0, orchestrator=orch_off,
                        scale_events=scales, step_time=0.02,
                        prefill_token_time=0.002)

    assert sorted(m_on.finished) == sorted(m_off.finished)
    assert m_on.outputs == m_off.outputs   # exact token identity


# --------------------------------------------------------------------------
# zero new jit traces across controller-driven reconfigurations
# --------------------------------------------------------------------------

def test_controller_zero_new_decode_traces():
    eng = make_engine(controller="on", victim_policy="controller",
                      max_ew=4, chunk_token_budget=32,
                      prefill_token_cap=256)
    orch = Orchestrator(eng, worker_init_time=0.4, weight_push_time=0.2)
    # warm the decode trace once, before any controller decision
    eng.generate("warm", PROMPT, 4)
    base = traces(eng)
    gen0 = eng.placement_generation
    run_serving(eng, mixed_workload(8.0), 60.0, orchestrator=orch,
                step_time=0.02, prefill_token_time=0.002)
    n_decisions = sum(v for k, v in eng.controller.counts.items()
                      if k != "preempt_denied")
    # the loop reconfigured the stack repeatedly (>= 5 decisions, with
    # placement generations among them) off one warm trace set
    assert n_decisions >= 5, eng.controller.counts
    assert eng.placement_generation > gen0
    assert traces(eng) == base


# --------------------------------------------------------------------------
# hysteresis: an oscillating load trace must not flap the pool
# --------------------------------------------------------------------------

def test_autoscale_no_flapping_under_oscillating_queue():
    eng = make_engine(controller="on", max_ew=4, chunk_token_budget=16)
    orch = Orchestrator(eng, worker_init_time=0.4, weight_push_time=0.2)
    ctl = eng.controller
    dwell = ctl._scale_dwell()
    assert dwell == 0.4 + 2 * 0.2   # T_push-aware default: T_w + 2*T_push
    rid = 0
    for i in range(60):
        t = i * 0.05
        if i % 2 == 0:     # burst: well above the scale-out watermark
            for _ in range(8):
                eng.gateway.enqueue(f"h{rid}", PROMPT, 4, now=t)
                rid += 1
        else:              # trough: queue drains completely
            for q in eng.gateway.queues.values():
                q.clear()
        ctl.tick(t)
        orch.tick(t)
    scale_ts = [d["t"] for d in ctl.decisions
                if d["kind"].startswith("scale")]
    # never shrinks in response to a transient trough...
    assert ctl.counts["scale_in"] == 0
    # ...and consecutive scale decisions are separated by >= the dwell
    assert all(b - a >= dwell - 1e-9
               for a, b in zip(scale_ts, scale_ts[1:])), scale_ts
    assert ctl.counts["scale_out"] >= 1   # the sustained EMA does react


# --------------------------------------------------------------------------
# deadline-aware preemption: gate + never-evict-interactive
# --------------------------------------------------------------------------

def test_controller_preemption_gate_and_interactive_immunity():
    eng = make_engine(controller="on", victim_policy="controller",
                      max_batch=4, ctl_autoscale=False,
                      ctl_rebalance=False)
    # fill every slot: half interactive, half batch
    for i in range(2):
        eng.client.submit(RequestSpec(rid=f"i{i}", prompt=PROMPT,
                                      max_new=20,
                                      slo_class="interactive"))
        eng.client.submit(RequestSpec(rid=f"b{i}", prompt=PROMPT,
                                      max_new=20, slo_class="batch"))
    eng.step(now=0.0)
    assert len(eng.active_requests()) == 4

    # a blocked interactive head with a DISTANT deadline: the gate denies
    # (nothing is at risk — evicting batch work would waste its progress)
    eng.client.submit(RequestSpec(rid="late", prompt=PROMPT, max_new=4,
                                  slo_class="interactive", deadline=100.0))
    eng.step(now=0.1)
    assert eng.controller.counts["preempt"] == 0
    assert eng.controller.counts["preempt_denied"] >= 1
    assert eng.gateway.stats.preemptions == 0

    # an IMMINENT deadline opens the gate: a batch victim is evicted,
    # interactive residents are untouchable by construction ("late" is
    # dropped first — a retried head pins the front of its class queue)
    eng.gateway.drop("late")
    eng.client.submit(RequestSpec(rid="soon", prompt=PROMPT, max_new=4,
                                  slo_class="interactive", deadline=0.25))
    eng.step(now=0.2)
    assert eng.gateway.stats.preemptions >= 1
    assert eng.controller.counts["preempt"] >= 1
    for i in range(2):
        r = eng.requests[f"i{i}"]
        assert r.preemptions == 0 and not r.queued_for_recovery
    assert any(eng.requests[f"b{i}"].preemptions == 1 or
               eng.requests[f"b{i}"].queued_for_recovery
               for i in range(2))


def test_controller_victim_pricing_prefers_low_kv_value():
    """Equal remaining work: the victim is the batch request with the
    LEAST resident KV to tear down (mid-prefill beats deep-decode)."""
    eng = make_engine(controller="on", victim_policy="controller",
                      max_batch=4, ctl_autoscale=False,
                      ctl_rebalance=False)
    eng.client.submit(RequestSpec(rid="deep", prompt=PROMPT, max_new=24,
                                  slo_class="batch"))
    eng.step(now=0.0)
    for _ in range(8):           # "deep" accumulates resident KV
        eng.step(now=0.0)
    eng.client.submit(RequestSpec(rid="shallow", prompt=PROMPT,
                                  max_new=24 - len(
                                      eng.requests["deep"].tokens),
                                  slo_class="batch"))
    eng.step(now=0.1)
    deep, shallow = eng.requests["deep"], eng.requests["shallow"]
    # same remaining work by construction; resident extents differ
    assert eng._remaining_work(deep) == eng._remaining_work(shallow)
    cands = [deep, shallow]
    victim = eng.controller.choose_victim(cands, head=None, now=0.2)
    assert victim.rid == "shallow"
    assert eng.controller._victim_kv_value(shallow) < \
        eng.controller._victim_kv_value(deep)


# --------------------------------------------------------------------------
# satellite: scale-out resets the auto-rebalance cooldown
# --------------------------------------------------------------------------

def test_scale_out_resets_rebalance_cooldown():
    """Regression: a long cooldown window used to swallow the rebalance a
    scale-out needs — the joiner sat idle until the window expired. The
    add_ew completion now resets the cooldown, so the very next auto
    pass ships load to the new EW."""
    eng = make_engine(max_ew=3)
    orch = Orchestrator(eng, worker_init_time=0.1, weight_push_time=0.1,
                        auto_rebalance=True, rebalance_cooldown=100.0)
    mgr = eng.placement_mgr
    plan = mgr.plan
    skew = np.where(plan.slot_owner == 0, 50.0, 1.0) * \
        (plan.slot_expert >= 0)
    for _ in range(5):
        mgr.record_slot_load(skew)
    assert mgr.should_rebalance()

    orch.tick(0.0)               # auto-rebalance #1 fires, cooldown opens
    orch.tick(0.2)               # ...and completes (T_push = 0.1)
    starts = [e for e in orch.events if e.kind == "rebalance_started"]
    assert len(starts) == 1
    orch.tick(0.3)               # still skewed, but inside the cooldown
    assert len([e for e in orch.events
                if e.kind == "rebalance_started"]) == 1

    orch.request_scale_out(0.4)  # t_ready = 0.4 + T_w + T_push = 0.6
    orch.tick(0.7)               # joiner lands; cooldown must reset
    assert any(e.kind == "scaled_out" for e in orch.events)
    starts = [e for e in orch.events if e.kind == "rebalance_started"]
    assert len(starts) == 2, [
        (e.t, e.kind) for e in orch.events]
    assert starts[1].t == 0.7    # immediately, not 100s later


# --------------------------------------------------------------------------
# weighted split replicas
# --------------------------------------------------------------------------

def test_weighted_splits_valid_and_no_worse_than_parity():
    def skewed_mgr(mode):
        eng = make_engine()
        mgr = eng.placement_mgr
        mgr.split_mode = mode
        rng = np.random.default_rng(3)
        heat = rng.zipf(1.5, size=mgr.plan.slot_expert.shape).astype(
            np.float64) * (mgr.plan.slot_expert >= 0)
        for _ in range(6):
            mgr.record_slot_load(heat)
        return mgr

    def predicted_imbalance(mgr, plan):
        load = mgr.load.ema_expert
        ew = {m: 0.0 for m in plan.members}
        for ex in range(len(plan.primary)):
            if plan.primary[ex] < 0:
                continue
            home = int(plan.slot_owner[plan.primary[ex]])
            if plan.split_slot[ex] >= 0:
                other = int(plan.slot_owner[plan.split_slot[ex]])
                ew[home] += load[ex] / 2
                ew[other] += load[ex] / 2
            else:
                ew[home] += load[ex]
        vals = np.asarray(list(ew.values()))
        return float(vals.max() / vals.mean()) if vals.sum() else 1.0

    m_w = skewed_mgr("weighted")
    plan_w = m_w.plan_rebalance()
    m_p = skewed_mgr("parity")
    plan_p = m_p.plan_rebalance()

    # structural validity: every expert placed; every split references a
    # slot assigned to the same expert on a DIFFERENT EW than its primary
    assert (plan_w.primary >= 0).all()
    for ex in range(len(plan_w.primary)):
        s = plan_w.split_slot[ex]
        if s >= 0:
            assert plan_w.slot_expert[s] == ex
            assert plan_w.slot_owner[s] != \
                plan_w.slot_owner[plan_w.primary[ex]]
    # sizing replicas to the measured deficit never loses to parity on
    # the predicted post-plan imbalance (same load, same slots)
    assert predicted_imbalance(m_w, plan_w) <= \
        predicted_imbalance(m_p, plan_p) + 1e-9


def test_parity_split_mode_unchanged_by_default():
    eng = make_engine()
    assert eng.placement_mgr.split_mode == "parity"
    eng_on = make_engine(controller="on", max_ew=4)
    assert eng_on.placement_mgr.split_mode == "weighted"


# --------------------------------------------------------------------------
# knob hygiene: controller="off" is byte-identical static behavior
# --------------------------------------------------------------------------

def test_controller_off_is_default_and_inert():
    eng = make_engine()
    assert eng.ecfg.controller == "off" and eng.controller is None
    ref = make_engine().generate("r", PROMPT, 10)
    assert make_engine().generate("r", PROMPT, 10) == ref


def test_controller_decisions_surface_in_telemetry():
    eng = make_engine(controller="on", victim_policy="controller",
                      max_ew=4, chunk_token_budget=32,
                      prefill_token_cap=256)
    orch = Orchestrator(eng, worker_init_time=0.4, weight_push_time=0.2)
    m = run_serving(eng, mixed_workload(), 60.0, orchestrator=orch,
                    step_time=0.02, prefill_token_time=0.002)
    snap = eng.telemetry.snapshot()
    assert snap["counters"]["controller.decisions.total"] == \
        sum(v for k, v in eng.controller.counts.items()
            if k != "preempt_denied") > 0
    # per-decision WorkerEvents became events.* counters + trace instants
    kinds = {d["kind"] for d in eng.controller.decisions}
    for k in kinds:
        assert snap["counters"][f"events.controller_{k}"] == \
            eng.controller.counts[k]
    chrome = eng.telemetry.export_chrome()
    names = {e.get("name") for e in chrome["traceEvents"]}
    assert any(k in names for k in
               (f"controller_{k}" for k in kinds))
    # and the audit history rides ServeMetrics
    assert m.controller["counts"] == eng.controller.counts
