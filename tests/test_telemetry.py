"""Observability plane (serving/telemetry.py): streaming histograms vs
exact percentiles, the multi-consumer event bus, per-request span
completeness, stall attribution, exporter formats — and the two hard
invariants across a serving run that spans an AW failure, preemptions, a
queued cancel, and a prefix-warm chat turn: telemetry on/off is
bit-identical, and the plane mints zero new jit traces."""
import json
import math

import jax
import numpy as np
import pytest

from conftest import reduced
from repro.core.costmodel import TarragonProfile
from repro.core.events import timeline_from_bus
from repro.core.orchestrator import Orchestrator, WorkerEvent
from repro.data.workloads import make_workload
from repro.serving.engine import EngineConfig, InferenceEngine
from repro.serving.scheduler import FailurePlan, run_serving
from repro.serving.telemetry import (SCHEMA, STALL_CAUSES, EventBus,
                                     MetricsRegistry, StreamingHistogram,
                                     attribute_gap, pct, summarize_latency)


# --------------------------------------------------------------------------
# percentile helpers
# --------------------------------------------------------------------------

def test_pct_empty_guard():
    assert pct([], 50) == 0.0
    assert pct(np.zeros((0,)), 99) == 0.0
    assert pct([3.0, 1.0, 2.0], 50) == 2.0


def test_summarize_latency():
    s = summarize_latency([])
    assert s["n"] == 0 and s["p99"] == 0.0
    s = summarize_latency([0.1] * 100)
    assert s["n"] == 100
    assert s["p50"] == pytest.approx(0.1)
    assert s["max"] == pytest.approx(0.1)


# --------------------------------------------------------------------------
# streaming histogram: O(1) memory, mergeable, bucket-bounded quantiles
# --------------------------------------------------------------------------

def exact_rank(vals: np.ndarray, q: float) -> float:
    """The order statistic the histogram's cumulative scan targets:
    smallest x with rank >= ceil(q * n)."""
    v = np.sort(np.asarray(vals))
    k = min(v.size - 1, max(0, math.ceil(q * v.size) - 1))
    return float(v[k])


def within_one_bucket(h: StreamingHistogram, streamed: float,
                      exact: float) -> bool:
    return abs(h.bucket_index(streamed) - h.bucket_index(exact)) <= 1


def test_histogram_quantiles_within_one_bucket():
    rng = np.random.default_rng(0)
    vals = rng.lognormal(mean=-3.0, sigma=1.2, size=5000)
    h = StreamingHistogram()
    for v in vals:
        h.observe(v)
    assert h.count == vals.size
    for q in (0.50, 0.95, 0.99):
        assert within_one_bucket(h, h.quantile(q), exact_rank(vals, q)), \
            (q, h.quantile(q), exact_rank(vals, q))
    # streamed never escapes the observed range
    assert h.quantile(0.0) >= float(vals.min()) - 1e-12
    assert h.quantile(1.0) <= float(vals.max()) + 1e-12


def test_histogram_constant_memory():
    h = StreamingHistogram()
    n_buckets = h.counts.size
    for v in np.random.default_rng(1).exponential(size=10000):
        h.observe(v)
    assert h.counts.size == n_buckets          # no per-sample state
    assert h.count == 10000


def test_histogram_merge_equals_union():
    rng = np.random.default_rng(2)
    a, b = rng.exponential(size=400), rng.exponential(size=700)
    ha, hb, hu = (StreamingHistogram() for _ in range(3))
    for v in a:
        ha.observe(v)
        hu.observe(v)
    for v in b:
        hb.observe(v)
        hu.observe(v)
    ha.merge(hb)
    assert ha.count == hu.count == 1100
    assert np.array_equal(ha.counts, hu.counts)
    assert ha.vmax == hu.vmax and ha.vmin == hu.vmin
    for q in (0.5, 0.99):
        assert ha.quantile(q) == hu.quantile(q)


def test_histogram_merge_rejects_incompatible_configs():
    with pytest.raises(AssertionError):
        StreamingHistogram(buckets_per_decade=32).merge(
            StreamingHistogram(buckets_per_decade=16))


def test_registry_snapshot_and_prometheus():
    r = MetricsRegistry()
    r.inc("requests.released", 3)
    r.gauge("queue_depth", 5.0)
    r.observe("ttft", 0.12)
    r.observe("ttft", 0.34)
    snap = r.snapshot()
    assert snap["schema"] == SCHEMA
    assert snap["counters"]["requests.released"] == 3
    assert snap["gauges"]["queue_depth"] == 5.0
    assert snap["histograms"]["ttft"]["count"] == 2
    text = r.prometheus_text()
    assert "tarragon_requests_released_total 3" in text
    assert "tarragon_queue_depth 5" in text
    assert 'tarragon_ttft_bucket{le="+Inf"} 2' in text
    assert "tarragon_ttft_count 2" in text


# --------------------------------------------------------------------------
# event bus: per-consumer cursors, nothing stolen
# --------------------------------------------------------------------------

def _ev(t, kind, worker="aw0"):
    return WorkerEvent(t, kind, worker)


def test_event_bus_multi_consumer_non_stealing():
    bus = EventBus()
    for i in range(3):
        bus.publish(_ev(float(i), "detected"))
    # two consumers each see the full stream
    assert len(bus.drain("a")) == 3
    assert len(bus.drain("b")) == 3
    assert len(bus.drain("a")) == 0            # cursor advanced, no repeat
    bus.publish(_ev(3.0, "provisioned"))
    assert [e.kind for e in bus.drain("a")] == ["provisioned"]
    assert [e.kind for e in bus.drain("b")] == ["provisioned"]
    # the underlying stream is still intact for late-joining consumers
    assert len(bus.events) == 4
    assert len(bus.drain("late")) == 4
    assert bus.cursor("a") == 4


def test_event_bus_cap_drops_newest_keeps_cursors_valid():
    """Past the cap the bus drops NEW events (counting them) rather than
    shifting old ones out — existing consumer cursors stay valid
    indices into an append-only stream."""
    bus = EventBus(max_events=4)
    for i in range(6):
        bus.publish(_ev(float(i), "k"))
    assert len(bus) == 4 and bus.dropped == 2
    assert [e.t for e in bus.drain("x")] == [0.0, 1.0, 2.0, 3.0]


def test_timeline_from_bus_is_a_second_consumer():
    bus = EventBus()
    bus.publish(WorkerEvent(0.5, "detected", "aw0", "heartbeat"))
    bus.publish(WorkerEvent(1.0, "provisioned", "aw2"))
    audit = bus.drain("audit")                 # first consumer
    lines = timeline_from_bus(bus)             # second, non-stealing
    assert len(audit) == 2
    assert lines == ["detected@0.50s aw0 (heartbeat)",
                     "provisioned@1.00s aw2"]
    assert timeline_from_bus(bus) == []        # own cursor advanced
    assert len(bus.events) == 2


# --------------------------------------------------------------------------
# stall attribution: clipped, prioritised, sums exactly
# --------------------------------------------------------------------------

def test_attribute_gap_sums_exactly_and_prioritises():
    comps = attribute_gap(0.0, 10.0, {
        "detection": [(-1.0, 3.0)],            # clipped to [0, 3]
        "queue_wait": [(2.0, 5.0)],            # [2,3] already claimed
        "prefill": [(4.5, 5.5)],               # [4.5,5] claimed by queue
    })
    assert comps["detection"] == pytest.approx(3.0)
    assert comps["queue_wait"] == pytest.approx(2.0)
    assert comps["prefill"] == pytest.approx(0.5)
    assert comps["execution"] == pytest.approx(4.5)
    assert sum(comps.values()) == pytest.approx(10.0, abs=1e-12)


def test_attribute_gap_empty_causes_is_all_execution():
    comps = attribute_gap(1.0, 2.5, {})
    assert comps["execution"] == pytest.approx(1.5)
    assert all(comps[c] == 0.0 for c in STALL_CAUSES)


# --------------------------------------------------------------------------
# the full scenario: AW failure + preemptions + queued cancel + prefix-warm
# chat turns, telemetry on vs off
# --------------------------------------------------------------------------

STEP = 0.02
PF_TOK = 0.002
_RUNS = {}


def _workload():
    slo = make_workload("mixed_slo", rate_rps=3.0, duration=2.0, seed=7,
                        max_new=40, interactive_deadline=0.3,
                        batch_wave=8, batch_every=3.0)
    chat = make_workload("multi_turn_chat", rate_rps=3.0, duration=2.0,
                         seed=11, chat_turns=2, chat_turn_gap=0.6,
                         chat_max_new=4)
    return sorted(slo + chat, key=lambda r: (r.arrival, r.request_id))


def scenario(telemetry: bool):
    """One serving run (cached per on/off) exercising every lifecycle
    path the plane traces: fresh admission, chunked prefill, preemption
    + requeue, AW failure + checkpoint restore, a prefix-warm chat turn,
    and a queued cancel."""
    if telemetry in _RUNS:
        return _RUNS[telemetry]
    cfg = reduced("mixtral_8x7b", cap_factor=4.0)
    ecfg = EngineConfig(max_batch=8, max_seq=96, num_aw=2, num_ew=2,
                        chunk_token_budget=16, prefix_cache_slots=4,
                        preempt=True, placement="session_affinity",
                        telemetry=telemetry, stall_threshold=0.1)
    eng = InferenceEngine(cfg, ecfg, jax.random.PRNGKey(1))
    orch = Orchestrator(eng, profile=TarragonProfile(detect=0.05,
                                                     detect_retries=2),
                        worker_init_time=0.5)
    # a request cancelled while still queued: no RequestState ever exists,
    # the root span must close through the drop path
    eng.gateway.enqueue("cx", np.arange(1, 9, dtype=np.int32), 4, now=0.0)
    assert eng.cancel_request("cx", now=0.0)
    m = run_serving(eng, _workload(), duration=60.0, orchestrator=orch,
                    failures=[FailurePlan(0.4, "aw", 0)],
                    step_time=STEP, prefill_token_time=PF_TOK)
    _RUNS[telemetry] = (eng, orch, m)
    return _RUNS[telemetry]


def test_scenario_covers_every_path():
    eng, orch, m = scenario(True)
    wl = _workload()
    assert len(m.finished) == len(wl)
    assert eng.gateway.stats.preemptions >= 1
    assert eng.gateway.stats.prefix_hits >= 1
    assert eng.store.stats.restores >= 1
    assert any(e.kind == "detected" for e in orch.events)


def test_telemetry_on_off_bit_identical():
    """The invariant the whole plane is built around: switching telemetry
    on cannot change a single token."""
    _, _, m_on = scenario(True)
    _, _, m_off = scenario(False)
    assert set(m_on.outputs) == set(m_off.outputs)
    for rid, toks in m_off.outputs.items():
        assert m_on.outputs[rid] == toks, rid
    assert m_on.finished == m_off.finished
    assert m_on.telemetry is not None and m_off.telemetry is None


def test_telemetry_mints_zero_new_jit_traces():
    eng_on, _, _ = scenario(True)
    eng_off, _, _ = scenario(False)

    def traces(eng):
        return eng._decode._cache_size() + eng.decode_plane.segment_traces()

    assert traces(eng_on) == traces(eng_off)
    # and the snapshot's own gauge agrees (sync() reads, never compiles)
    snap = eng_on.telemetry.snapshot()
    assert snap["gauges"]["jit.decode_traces"] == traces(eng_on)
    assert traces(eng_on) == eng_on._decode._cache_size() + \
        eng_on.decode_plane.segment_traces()


def test_every_request_closes_exactly_one_root_span():
    """Admitted, preempted, failed-over, prefix-warm, and queued-cancelled
    requests all close exactly one root span — none dangle, none double."""
    eng, _, m = scenario(True)
    tel = m.telemetry
    rids = {w.request_id for w in _workload()} | {"cx"}
    assert set(tel.closed_roots) == rids
    assert all(n == 1 for n in tel.closed_roots.values()), tel.closed_roots
    assert not tel._root                       # nothing left open
    assert not tel._phase
    snap = tel.snapshot()
    assert snap["spans"]["open_roots"] == 0
    assert snap["counters"]["requests.outcome.cancelled"] == 1
    assert snap["counters"]["requests.outcome.done"] == len(_workload())


def test_stall_components_sum_to_gap():
    _, _, m = scenario(True)
    rep = m.telemetry.stall_report()
    assert rep                                  # the failure forced stalls
    for s in rep:
        assert s["gap"] > m.telemetry.stall_threshold
        assert abs(sum(s["components"].values()) - s["gap"]) < 1e-9, s
        assert all(v >= -1e-12 for v in s["components"].values()), s
    causes = {c for s in rep
              for c, v in s["components"].items() if v > 1e-12}
    # the AW failure must be visible in the attribution: its victims'
    # stalls carry restore (failover requeue) time, and the preemption
    # plane's victims carry preemption time
    assert "restore" in causes, causes
    assert "preemption" in causes, causes
    assert "execution" in causes


def test_streamed_percentiles_match_exact_within_one_bucket():
    """The registry's O(1) histograms reproduce the exact per-token lists
    ServeMetrics keeps: identical counts, identical gap stream (p50 of
    TBT is exact), and every quantile within one log bucket of the order
    statistic."""
    _, _, m = scenario(True)
    tel = m.telemetry
    tbt_e, ttft_e = m.tbt_values(), m.ttft_values()
    h_tbt, h_ttft = tel.registry.hist("tbt"), tel.registry.hist("ttft")
    assert h_tbt.count == tbt_e.size           # same stream, same length
    assert h_ttft.count == ttft_e.size
    assert h_tbt.quantile(0.5) == pytest.approx(exact_rank(tbt_e, 0.5),
                                                rel=0.08)
    for h, vals in ((h_tbt, tbt_e), (h_ttft, ttft_e)):
        for q in (0.50, 0.95, 0.99):
            assert within_one_bucket(h, h.quantile(q),
                                     exact_rank(vals, q)), \
                (q, h.quantile(q), exact_rank(vals, q))
    # sums match too (histogram keeps a running total)
    assert h_tbt.total == pytest.approx(float(tbt_e.sum()), rel=1e-6)


def test_per_class_histograms_partition_the_stream():
    _, _, m = scenario(True)
    tel = m.telemetry
    classes = set(m.slo_class.values())
    assert {"interactive", "batch", "standard"} <= classes
    n_by_class = sum(tel.registry.hist(f"tbt.{c}").count for c in classes)
    assert n_by_class == tel.registry.hist("tbt").count
    for c in classes:
        assert tel.registry.hist(f"tbt.{c}").count == m.tbt_values(c).size


def test_snapshot_schema_and_mirrored_stats():
    eng, _, m = scenario(True)
    snap = m.telemetry.snapshot()
    assert snap["schema"] == SCHEMA
    for key in ("counters", "gauges", "histograms", "clock", "stalls",
                "spans"):
        assert key in snap, key
    gs = eng.gateway.stats
    assert snap["counters"]["gateway.preemptions"] == gs.preemptions
    assert snap["counters"]["gateway.prefix_hits"] == gs.prefix_hits
    assert snap["counters"]["events.preempted"] == gs.preemptions
    assert snap["gauges"]["gateway.queue_depth"] == 0
    assert snap["gauges"]["ew.live"] == len(eng.live_ews)
    # every admission (including the re-admissions of preempted and
    # failed-over requests) observed a queueing delay
    assert snap["histograms"]["queue_delay"]["count"] >= len(m.queue_delay)
    assert json.loads(json.dumps(snap)) == snap   # JSON-serialisable


def test_prometheus_export_shape():
    _, _, m = scenario(True)
    text = m.telemetry.prometheus_text()
    lines = text.splitlines()
    assert any(ln.startswith("tarragon_ttft_bucket{le=") for ln in lines)
    assert any('le="+Inf"' in ln for ln in lines)
    assert any(ln.startswith("tarragon_gateway_admitted_total ")
               for ln in lines)
    # cumulative bucket counts are monotone
    cum = [float(ln.rsplit(" ", 1)[1]) for ln in lines
           if ln.startswith("tarragon_tbt_bucket{")]
    assert cum == sorted(cum) and cum[-1] > 0


def test_chrome_trace_export(tmp_path):
    """Perfetto-loadable trace: process/thread metadata, complete spans
    with ts+dur on the virtual clock (µs), the failure's detection span
    on the workers track, and stall spans carrying their attribution."""
    eng, orch, m = scenario(True)
    path = tmp_path / "trace.json"
    trace = m.telemetry.export_chrome(str(path))
    on_disk = json.loads(path.read_text())
    assert on_disk == trace
    evs = trace["traceEvents"]
    assert any(e["ph"] == "M" and e["name"] == "thread_name" for e in evs)
    xs = [e for e in evs if e["ph"] == "X"]
    assert xs and all(e["dur"] >= 0 and e["ts"] >= 0 for e in xs)
    det = [e for e in xs if e["name"].startswith("detect_aw")]
    assert len(det) == 1
    t_detect = next(e.t for e in orch.events if e.kind == "detected")
    assert det[0]["ts"] + det[0]["dur"] == pytest.approx(t_detect * 1e6)
    stall = [e for e in xs if e["name"].startswith("stall(")]
    assert stall
    assert any(e["args"].get("restore", 0) > 0 for e in stall)
    # every workload request has a root span event named after its rid
    names = {e["name"] for e in xs}
    assert {w.request_id for w in _workload()} <= names


def test_telemetry_off_engine_has_no_plane():
    eng, _, _ = scenario(False)
    assert eng.telemetry is None
    assert eng.gateway.telemetry is None
    # the bus still runs (it is the audit stream, not the telemetry plane)
    assert len(eng.bus.events) > 0
