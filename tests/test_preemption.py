"""Preempt-and-requeue tests: planned eviction rides the recovery path.

The bar mirrors the failure tests' exactness claim, applied to evictions
the scheduler *chose*: a preempted request resumes from its committed
checkpoint cursor (never from token 0), its tokens are bit-identical to
the unpreempted run, mid-chunked-prefill and mid-decode alike, and no
placement/preemption transition ever triggers a new jit trace of the
decode step."""
import dataclasses

import jax
import numpy as np

from conftest import reduced
from repro.serving.api import RequestSpec
from repro.serving.engine import EngineConfig, InferenceEngine

PROMPT = np.arange(1, 9, dtype=np.int32)
LONG_PROMPT = np.arange(1, 33, dtype=np.int32)


def make_engine(**kw):
    cfg = reduced("mixtral_8x7b", cap_factor=4.0)
    defaults = dict(max_batch=4, max_seq=64, num_aw=2, num_ew=2)
    defaults.update(kw)
    return InferenceEngine(cfg, EngineConfig(**defaults),
                           jax.random.PRNGKey(7))


def run_all(eng, handles, max_steps=500):
    n = 0
    while not all(h.done() for h in handles) and n < max_steps:
        eng.step()
        for rid in [r.rid for r in eng.requests.values() if r.done]:
            eng.release_request(rid)
        n += 1
    assert all(h.done() for h in handles)


# --------------------------------------------------------------------------
# bit-identity
# --------------------------------------------------------------------------

def test_preempt_mid_decode_bit_identical():
    ref = make_engine().generate("r", PROMPT, 14)
    eng = make_engine()
    h = eng.client.submit(RequestSpec(rid="r", prompt=PROMPT, max_new=14,
                                      slo_class="batch"))
    for _ in range(4):
        eng.step()
    n_before = len(h.tokens())
    assert eng.preempt_request("r", now=1.0)
    assert h.state() == "preempted"
    # planned eviction flushes the watermark: zero tokens rewound
    assert len(eng.requests["r"].tokens) == n_before
    while not h.done():
        eng.step()
    assert h.tokens() == ref
    assert h.status().preemptions == 1
    # direct evictions count in the same place as hook-driven ones
    assert eng.gateway.stats.preemptions == 1
    assert eng.store.stats.restores == 1      # resumed via §6.2, once


def test_preempt_mid_chunked_prefill_resumes_from_cursor():
    kw = dict(chunk_token_budget=8, prefill_bucket=16)
    ref = make_engine(**kw).generate("r", LONG_PROMPT, 10)
    eng = make_engine(**kw)
    h = eng.client.submit(RequestSpec(rid="r", prompt=LONG_PROMPT,
                                      max_new=10, slo_class="batch"))
    eng.step()
    r = eng.requests["r"]
    assert r.prefilling and 0 < r.prefill_cursor < len(LONG_PROMPT) - 1
    cursor = r.prefill_cursor
    assert eng.preempt_request("r", now=1.0)
    while not h.done():
        eng.step()
    assert h.tokens() == ref
    assert eng.chunked.stats.resumed == 1
    # no from-token-0 re-prefill: the committed prefix [0, cursor) was
    # restored, so total chunk work equals the prompt exactly
    assert eng.chunked.stats.prefilled_tokens["r"] == len(LONG_PROMPT) - 1
    assert eng.chunked.stats.restored_tokens["r"] == cursor


def test_repeated_preemption_is_exact():
    ref = make_engine().generate("r", PROMPT, 16)
    eng = make_engine()
    h = eng.client.submit(RequestSpec(rid="r", prompt=PROMPT, max_new=16,
                                      slo_class="batch"))
    for k in range(3):
        for _ in range(2):
            eng.step()
        assert eng.preempt_request("r", now=float(k))
        eng.step()                    # recovery entry re-admits
    while not h.done():
        eng.step()
    assert h.tokens() == ref
    assert h.status().preemptions == 3


def test_preempt_without_per_token_checkpointing_uses_bulk_path():
    """checkpoint=False engines have no async stream; planned eviction
    bulk-checkpoints the victim's whole resident prefix through
    KVCheckpointer.checkpoint_range and still resumes exactly."""
    ref = make_engine(checkpoint=False).generate("r", PROMPT, 12)
    eng = make_engine(checkpoint=False)
    h = eng.client.submit(RequestSpec(rid="r", prompt=PROMPT, max_new=12,
                                      slo_class="batch"))
    for _ in range(4):
        eng.step()
    assert eng.store.stats.updates == 0       # nothing streamed so far
    assert eng.preempt_request("r", now=1.0)
    assert eng.store.stats.updates > 0        # the bulk segments landed
    resume_from = eng.store.committed_token("r")
    assert resume_from == eng.requests["r"].pos - 1
    while not h.done():
        eng.step()
    assert h.tokens() == ref


# --------------------------------------------------------------------------
# gateway-triggered preemption (the admission plane's hook)
# --------------------------------------------------------------------------

def test_interactive_preempts_saturating_batch():
    prompts = {f"b{i}": PROMPT + i for i in range(4)}
    refs = {rid: make_engine().generate(rid, p, 24)
            for rid, p in prompts.items()}
    eng = make_engine()
    bh = [eng.client.submit(RequestSpec(rid=rid, prompt=p, max_new=24,
                                        slo_class="batch"))
          for rid, p in prompts.items()]
    for _ in range(3):
        eng.step()
    assert all(not w.has_capacity() for w in eng.aws)
    hi = eng.client.submit(RequestSpec(rid="int", prompt=PROMPT + 9,
                                       max_new=4, slo_class="interactive"),
                           now=1.0)
    # placed immediately: a batch victim was checkpointed out of its slot
    assert hi.state() == "placed"
    assert eng.gateway.stats.preemptions == 1
    assert sum(1 for h in bh if h.state() == "preempted") == 1
    victim = next(h for h in bh if h.state() == "preempted")
    # the youngest admit is the victim (elders are closer to done)
    assert victim.rid == "b3"
    assert any(e.kind == "preempted" and e.worker == "b3"
               for e in eng.request_log)
    run_all(eng, bh + [hi])
    for rid, ref in refs.items():
        assert eng.client.handle(rid).tokens() == ref, rid
    ref_int = make_engine().generate("int", PROMPT + 9, 4)
    assert hi.tokens() == ref_int


def test_standard_class_never_preempts():
    eng = make_engine()
    for i in range(4):
        eng.client.submit(RequestSpec(rid=f"b{i}", prompt=PROMPT,
                                      max_new=30, slo_class="batch"))
    hs = eng.client.submit(RequestSpec(rid="s", prompt=PROMPT, max_new=4,
                                       slo_class="standard"))
    assert hs.state() == "queued"
    assert eng.gateway.stats.preemptions == 0


def test_preempt_disabled_by_config():
    eng = make_engine(preempt=False)
    for i in range(4):
        eng.client.submit(RequestSpec(rid=f"b{i}", prompt=PROMPT,
                                      max_new=30, slo_class="batch"))
    hi = eng.client.submit(RequestSpec(rid="int", prompt=PROMPT,
                                       max_new=4,
                                       slo_class="interactive"))
    assert hi.state() == "queued"
    assert eng.gateway.stats.preemptions == 0


# --------------------------------------------------------------------------
# victim selection policies
# --------------------------------------------------------------------------

def test_remaining_work_policy_evicts_most_remaining():
    """Default victim selection: the batch request with the MOST work left
    is evicted — it has invested the least. Here b-short is younger but
    nearly done; b-long (older, huge max_new) must be the victim."""
    eng = make_engine()          # victim_policy defaults to remaining_work
    hl = eng.client.submit(RequestSpec(rid="b-long", prompt=PROMPT,
                                       max_new=40, slo_class="batch"))
    for _ in range(2):
        eng.step()
    hs = [eng.client.submit(RequestSpec(rid=f"b-short{i}", prompt=PROMPT + i,
                                        max_new=6, slo_class="batch"),
                            now=1.0) for i in range(3)]
    for _ in range(2):
        eng.step()
    assert all(not w.has_capacity() for w in eng.aws)
    hi = eng.client.submit(RequestSpec(rid="int", prompt=PROMPT + 9,
                                       max_new=2, slo_class="interactive"),
                           now=2.0)
    assert hi.state() == "placed"
    # under youngest-admit the victim would be a b-short; remaining-work
    # picks the long request despite its earlier arrival
    assert hl.state() == "preempted"
    assert all(h.state() != "preempted" for h in hs)


def test_remaining_work_weighs_prefill_debt():
    """A mid-prefill victim owes its whole prompt tail on top of its
    decode budget: with equal max_new, the request still prefilling is
    the cheapest to push aside (and resumes from its cursor)."""
    kw = dict(chunk_token_budget=4, prefill_bucket=16)
    eng = make_engine(**kw)
    done_h = [eng.client.submit(RequestSpec(rid=f"d{i}", prompt=PROMPT + i,
                                            max_new=20, slo_class="batch"))
              for i in range(3)]
    for _ in range(3):
        eng.step()                 # d* finish prefill, start decoding
    hp = eng.client.submit(RequestSpec(rid="pf", prompt=LONG_PROMPT,
                                       max_new=20, slo_class="batch"),
                           now=1.0)
    eng.step()                     # pf mid-chunked-prefill
    r = eng.requests["pf"]
    assert r.prefilling and r.prefill_cursor < len(LONG_PROMPT) - 1
    hi = eng.client.submit(RequestSpec(rid="int", prompt=PROMPT + 9,
                                       max_new=2, slo_class="interactive"),
                           now=2.0)
    assert hi.state() in ("placed", "prefilling")   # admitted immediately
    assert hp.state() == "preempted"       # largest prefill debt
    assert all(h.state() != "preempted" for h in done_h)
    run_all(eng, done_h + [hp, hi])
    ref = make_engine(**kw).generate("pf", LONG_PROMPT, 20)
    assert hp.tokens() == ref              # resume is still exact


def test_youngest_policy_pinned_behavior():
    """victim_policy="youngest" preserves the pre-remaining-work
    behavior: the latest arrival is evicted even if it has less work
    left than an older resident."""
    eng = make_engine(victim_policy="youngest")
    hl = eng.client.submit(RequestSpec(rid="b-long", prompt=PROMPT,
                                       max_new=40, slo_class="batch"))
    for _ in range(2):
        eng.step()
    hy = [eng.client.submit(RequestSpec(rid=f"b-young{i}",
                                        prompt=PROMPT + i, max_new=6,
                                        slo_class="batch"), now=1.0)
          for i in range(3)]
    for _ in range(2):
        eng.step()
    hi = eng.client.submit(RequestSpec(rid="int", prompt=PROMPT + 9,
                                       max_new=2, slo_class="interactive"),
                           now=2.0)
    assert hi.state() == "placed"
    assert hl.state() != "preempted"
    assert sum(1 for h in hy if h.state() == "preempted") == 1


# --------------------------------------------------------------------------
# zero-new-jit-trace invariant (the placement plane's bar, extended)
# --------------------------------------------------------------------------

def test_preemption_triggers_no_new_decode_traces():
    eng = make_engine()
    h = eng.client.submit(RequestSpec(rid="r", prompt=PROMPT, max_new=20,
                                      slo_class="batch"))
    for _ in range(3):
        eng.step()
    traces = eng._decode._cache_size()
    assert eng.preempt_request("r", now=1.0)
    while not h.done():
        eng.step()
    assert eng._decode._cache_size() == traces
