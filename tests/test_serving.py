"""Serving loop integration: arrivals, continuous batching, metrics, and
failure injection through the scheduler."""
import dataclasses

import jax
import numpy as np
import pytest

from conftest import reduced
from repro.core.orchestrator import Orchestrator
from repro.data.workloads import lm_batches, make_workload, poisson_arrivals
from repro.serving.engine import EngineConfig, InferenceEngine
from repro.serving.scheduler import FailurePlan, run_serving


def small_workload(n=5, prompt=6, out=8):
    wl = make_workload("random", rate_rps=3.0, duration=3.0, seed=2)
    wl = [dataclasses.replace(w, prompt_len=prompt, max_new_tokens=out)
          for w in wl]
    return wl[:n]


def make_engine(**kw):
    cfg = reduced("mixtral_8x7b", cap_factor=4.0)
    ecfg = EngineConfig(max_batch=8, max_seq=64, num_aw=2, num_ew=2, **kw)
    return InferenceEngine(cfg, ecfg, jax.random.PRNGKey(0))


def test_serving_completes_all_requests():
    eng = make_engine()
    wl = small_workload()
    m = run_serving(eng, wl, duration=100.0, step_time=0.05)
    assert len(m.finished) == len(wl)
    assert len(m.token_log) >= len(wl) * 7   # first token comes via prefill
    assert m.throughput() > 0
    # slots all released
    assert sum(eng.slots.free_count(a) for a in range(2)) == 8


def test_serving_with_ew_failure_finishes():
    eng = make_engine()
    orch = Orchestrator(eng, worker_init_time=0.5)
    wl = small_workload()
    m = run_serving(eng, wl, duration=100.0, orchestrator=orch,
                    failures=[FailurePlan(0.3, "ew", 0)], step_time=0.05)
    assert len(m.finished) == len(wl)
    assert any(e.kind == "detected" for e in orch.events)
    assert any(e.kind == "provisioned" for e in orch.events)


def test_serving_with_aw_failure_finishes():
    eng = make_engine()
    orch = Orchestrator(eng, worker_init_time=0.5)
    wl = [dataclasses.replace(w, arrival=0.0)
          for w in small_workload(out=40)]  # still running at failure time
    m = run_serving(eng, wl, duration=100.0, orchestrator=orch,
                    failures=[FailurePlan(0.15, "aw", 0)], step_time=0.05)
    assert len(m.finished) == len(wl)
    assert eng.store.stats.restores >= 1


def test_gateway_least_loaded_assignment():
    eng = make_engine()
    p = np.arange(1, 7, dtype=np.int32)
    eng.submit("a", p, 4)
    eng.submit("b", p, 4)
    eng.submit("c", p, 4)
    eng.submit("d", p, 4)
    aws = [eng.requests[r].aw for r in "abcd"]
    assert sorted(aws) == [0, 0, 1, 1]  # balanced across AWs


def test_metrics_tbt_and_timeline():
    eng = make_engine()
    wl = small_workload(3)
    m = run_serving(eng, wl, duration=100.0, step_time=0.05)
    tbt = m.tbt_values()
    assert tbt.size > 0 and np.all(tbt >= 0)
    t, thr = m.throughput_timeline(dt=0.5)
    assert t.shape == thr.shape and thr.max() > 0


def test_poisson_and_workload_kinds():
    rng = np.random.default_rng(0)
    arr = poisson_arrivals(10.0, 5.0, rng)
    assert np.all(np.diff(arr) >= 0)
    assert 20 <= len(arr) <= 90
    for kind, plen in (("random", 10), ("sharegpt", None)):
        wl = make_workload(kind, 5.0, 4.0, seed=1)
        assert wl
        if plen:
            assert all(w.prompt_len == plen for w in wl)


def test_lm_batches_deterministic():
    a = list(lm_batches(100, 2, 8, 3, seed=5))
    b = list(lm_batches(100, 2, 8, 3, seed=5))
    for x, y in zip(a, b):
        np.testing.assert_array_equal(x["tokens"], y["tokens"])
        np.testing.assert_array_equal(x["labels"], y["labels"])
