"""Compound-failure tests: multiple workers dying inside each other's
recovery windows. The bar is the paper's end-to-end exactness claim applied
to *overlapping* failures — no lost requests, outputs bit-identical to the
failure-free run — which exercises the elastic placement plane's pinned
failover replicas (plan_reprotect's dead_ews contract) and the per-request
restoration path simultaneously. Since the typed request API the same bar
covers the *scheduling* substrate: cancellation landing inside a recovery
window, and mixed-SLO workloads whose preemptions overlap AW+EW failures."""
import dataclasses

import jax
import numpy as np

from conftest import reduced
from repro.core.orchestrator import Orchestrator
from repro.serving.api import RequestSpec
from repro.serving.engine import EngineConfig, InferenceEngine

PROMPT_A = np.arange(1, 9, dtype=np.int32)
PROMPT_B = np.arange(2, 10, dtype=np.int32)


def make_engine(num_ew=2, num_shadow=None, **kw):
    cfg = reduced("mixtral_8x7b", cap_factor=4.0)
    if num_shadow is not None:
        cfg = dataclasses.replace(cfg, moe=dataclasses.replace(
            cfg.moe, num_shadow_slots=num_shadow))
    defaults = dict(max_batch=8, max_seq=48, num_aw=2, num_ew=num_ew)
    defaults.update(kw)
    return InferenceEngine(cfg, EngineConfig(**defaults),
                           jax.random.PRNGKey(7))


def _dual_protected_engine():
    """3-EW pool with a custom placement generation: every expert of EW0
    AND EW1 has a failover replica on EW2 (the default layout only protects
    one EW at a time). 4 experts over 3 EWs with 6 shadow slots -> EW2 owns
    exactly 4 slots (primary pads 4,5 + shadows 8,11)."""
    eng = make_engine(num_ew=3, num_shadow=6)
    mgr = eng.placement_mgr
    p = eng.api.placement
    assert p.primary_slots == 6 and p.num_slots == 12
    owner = p.slot_owner()
    ew2_slots = [s for s in range(p.num_slots) if owner[s] == 2]
    assert len(ew2_slots) == 4
    slot_expert = np.full((p.num_slots,), -1, np.int32)
    slot_expert[:4] = np.arange(4)                  # identity primaries
    for ex, s in enumerate(ew2_slots):              # all replicas on EW2
        slot_expert[s] = ex
    plan = mgr.adopt(slot_expert, reason="dual protect ew0+ew1")
    eng.install_plan(plan)
    cand = plan.candidates()
    assert all(cand[e, 1] >= 0 and owner[cand[e, 1]] == 2 for e in range(4))
    return eng


def test_ew_dies_while_other_ew_mid_provision():
    """EW0 fails; while its replacement is still provisioning (T_w), EW1
    fails too. With both EWs' experts replica-covered on EW2, every token
    matches the failure-free run and nothing is lost."""
    ref_a = _dual_protected_engine().generate("a", PROMPT_A, 16)
    ref_b = _dual_protected_engine().generate("b", PROMPT_B, 16)

    eng = _dual_protected_engine()
    orch = Orchestrator(eng, worker_init_time=1.0, weight_push_time=0.2)
    eng.submit("a", PROMPT_A, 16)
    eng.submit("b", PROMPT_B, 16)
    for _ in range(4):
        eng.step()
    orch.inject_failure("ew", 0, now=10.0)
    fired = orch.tick(10.0 + orch.detection_latency() + 1e-6)
    assert any(e.kind == "detected" for e in fired)
    assert eng.failed_ews == {0}
    for _ in range(3):
        eng.step()
    # EW0's replacement is mid-provision (ready ~11.03+T_w) when EW1 dies
    orch.inject_failure("ew", 1, now=10.5)
    fired = orch.tick(10.5 + orch.detection_latency() + 1e-6)
    assert any(e.kind == "detected" for e in fired)
    assert eng.failed_ews == {0, 1}
    while eng.active_requests():
        eng.step()
    assert eng.requests["a"].tokens == ref_a
    assert eng.requests["b"].tokens == ref_b
    # both replacements eventually provision; re-pointing while EW1 was
    # still down must have pinned its failover replicas (dead_ews contract)
    orch.tick(11.2)
    assert eng.failed_ews == {1}
    from repro.core import selfheal
    assert selfheal.experts_without_healthy_replica(
        eng.route_state, eng.api.placement).size == 0
    orch.tick(11.8)
    assert eng.failed_ews == set()
    assert orch.outstanding == 0


def test_aw_and_ew_die_in_same_detection_window():
    """AW0 and EW0 fail inside one detection window: per-request restoration
    (checkpointed KV onto AW1) composes with the shadow failover (EW0's
    experts re-pointed to replicas) — bit-identical, nothing lost."""
    ref_a = make_engine().generate("a", PROMPT_A, 14)
    ref_b = make_engine().generate("b", PROMPT_B, 14)

    eng = make_engine()
    orch = Orchestrator(eng, worker_init_time=1.0)
    eng.submit("a", PROMPT_A, 14)     # -> AW0 (least loaded, lowest id)
    eng.submit("b", PROMPT_B, 14)     # -> AW1
    for _ in range(4):
        eng.step()
    assert eng.requests["a"].aw == 0 and eng.requests["b"].aw == 1
    orch.inject_failure("aw", 0, now=5.0)
    orch.inject_failure("ew", 0, now=5.0)
    fired = orch.tick(5.0 + orch.detection_latency() + 1e-6)
    assert sorted(e.kind for e in fired) == ["detected", "detected"]
    assert eng.failed_aws == {0} and eng.failed_ews == {0}
    assert eng.requests["a"].aw == 1          # restored onto the healthy AW
    while eng.active_requests():
        eng.step()
    assert eng.requests["a"].tokens == ref_a
    assert eng.requests["b"].tokens == ref_b
    assert eng.store.stats.restores == 1
    # background provisioning restores the full pool
    orch.tick(7.0)
    assert eng.failed_aws == set() and eng.failed_ews == set()
    assert orch.outstanding == 0


def test_compound_failure_during_chunked_prefill():
    """AW dies mid-chunked-prefill AND an EW dies in the same window: the
    prefill stream resumes from its committed cursor on the healthy AW
    while expert traffic rides the shadows — the finished output equals the
    failure-free run's."""
    long_prompt = np.arange(1, 33, dtype=np.int32)
    kw = dict(chunk_token_budget=8, prefill_bucket=16, max_seq=64)
    ref = make_engine(**kw).generate("r", long_prompt, 10)

    eng = make_engine(**kw)
    orch = Orchestrator(eng, worker_init_time=1.0)
    eng.submit("r", long_prompt, 10)
    eng.step()                                  # a budgeted chunk lands
    r = eng.requests["r"]
    assert r.prefilling and r.prefill_cursor > 0
    aw = r.aw
    orch.inject_failure("aw", aw, now=3.0)
    orch.inject_failure("ew", 0, now=3.0)
    orch.tick(3.0 + orch.detection_latency() + 1e-6)
    while not eng.requests["r"].done:
        eng.step()
    assert eng.requests["r"].tokens == ref
    assert eng.chunked.stats.resumed == 1       # stream resumed, not redone


def test_cancel_during_aw_recovery_loses_no_other_request():
    """AW0 dies holding two requests; one of them is cancelled inside the
    recovery window (restored-or-still-queued). The cancellation must tear
    down cleanly — no stale recovery entry, no slot or store leak — and
    every surviving request must still finish bit-identical."""
    ref_b = make_engine(max_batch=4).generate("b", PROMPT_B, 14)
    ref_c = make_engine(max_batch=4).generate("c", PROMPT_A + 1, 14)

    eng = make_engine(max_batch=4)        # 2 slots per AW
    orch = Orchestrator(eng, worker_init_time=1.0)
    # least_loaded: a -> AW0, b -> AW1, c -> AW0 (tie toward lowest id)
    ha = eng.client.submit(RequestSpec(rid="a", prompt=PROMPT_A,
                                       max_new=14))
    hb = eng.client.submit(RequestSpec(rid="b", prompt=PROMPT_B,
                                       max_new=14))
    hc = eng.client.submit(RequestSpec(rid="c", prompt=PROMPT_A + 1,
                                       max_new=14))
    assert eng.requests["a"].aw == 0 and eng.requests["c"].aw == 0
    assert eng.requests["b"].aw == 1
    for _ in range(4):
        eng.step()
    orch.inject_failure("aw", 0, now=5.0)
    orch.tick(5.0 + orch.detection_latency() + 1e-6)
    # AW1 had one free slot: one victim restored, the other still queued
    assert eng.gateway.depth() == 1
    # cancel "a" inside the recovery window, whichever side it landed on
    assert ha.cancel(now=5.1)
    assert ha.state() == "cancelled"
    assert eng.gateway.find("a") is None      # no stale recovery entry
    assert "a" not in eng.requests
    while not (hb.done() and hc.done()):
        eng.step()
    assert hb.tokens() == ref_b
    assert hc.tokens() == ref_c               # the other victim lost nothing
    # background provisioning restores the full pool; slot accounting is
    # clean once the survivors release
    orch.tick(7.0)
    eng.release_request("b")
    eng.release_request("c")
    assert sum(w.slots.free_count() for w in eng.aws) == 4
    assert not eng.store.active_requests_on(0)


def test_mixed_class_workload_with_preemption_under_aw_ew_failure():
    """The full stack at once: a batch wave saturates the pool, an
    interactive arrival preempts a victim, then an AW and an EW die in the
    same detection window. Every request — preempted, restored, rerouted —
    finishes bit-identical to its failure-free run."""
    prompts = {f"b{i}": PROMPT_A + i for i in range(4)}
    prompts["int"] = PROMPT_B
    refs = {rid: make_engine(max_batch=4).generate(rid, p, 18)
            for rid, p in prompts.items()}

    eng = make_engine(max_batch=4)
    orch = Orchestrator(eng, worker_init_time=1.0)
    handles = {rid: eng.client.submit(RequestSpec(
        rid=rid, prompt=prompts[rid], max_new=18, slo_class="batch"))
        for rid in ("b0", "b1", "b2", "b3")}
    for _ in range(3):
        eng.step()
    handles["int"] = eng.client.submit(RequestSpec(
        rid="int", prompt=prompts["int"], max_new=18,
        slo_class="interactive"), now=4.0)
    assert eng.gateway.stats.preemptions == 1   # a victim was evicted
    orch.inject_failure("aw", 0, now=5.0)
    orch.inject_failure("ew", 0, now=5.0)
    orch.tick(5.0 + orch.detection_latency() + 1e-6)
    n = 0
    while not all(h.done() for h in handles.values()) and n < 600:
        eng.step()
        orch.tick(6.0 + 0.01 * n)
        for rid in [r.rid for r in eng.requests.values() if r.done]:
            eng.release_request(rid)
        n += 1
    for rid, ref in refs.items():
        assert handles[rid].tokens() == ref, rid
    # preempted/cancelled/deadline events rode the orchestrator timeline
    assert any(e.kind == "preempted" for e in orch.events)
    assert eng.store.stats.restores >= 2        # preemption + AW recovery
