"""Compound-failure tests: multiple workers dying inside each other's
recovery windows. The bar is the paper's end-to-end exactness claim applied
to *overlapping* failures — no lost requests, outputs bit-identical to the
failure-free run — which exercises the elastic placement plane's pinned
failover replicas (plan_reprotect's dead_ews contract) and the per-request
restoration path simultaneously."""
import dataclasses

import jax
import numpy as np

from conftest import reduced
from repro.core.orchestrator import Orchestrator
from repro.serving.engine import EngineConfig, InferenceEngine

PROMPT_A = np.arange(1, 9, dtype=np.int32)
PROMPT_B = np.arange(2, 10, dtype=np.int32)


def make_engine(num_ew=2, num_shadow=None, **kw):
    cfg = reduced("mixtral_8x7b", cap_factor=4.0)
    if num_shadow is not None:
        cfg = dataclasses.replace(cfg, moe=dataclasses.replace(
            cfg.moe, num_shadow_slots=num_shadow))
    defaults = dict(max_batch=8, max_seq=48, num_aw=2, num_ew=num_ew)
    defaults.update(kw)
    return InferenceEngine(cfg, EngineConfig(**defaults),
                           jax.random.PRNGKey(7))


def _dual_protected_engine():
    """3-EW pool with a custom placement generation: every expert of EW0
    AND EW1 has a failover replica on EW2 (the default layout only protects
    one EW at a time). 4 experts over 3 EWs with 6 shadow slots -> EW2 owns
    exactly 4 slots (primary pads 4,5 + shadows 8,11)."""
    eng = make_engine(num_ew=3, num_shadow=6)
    mgr = eng.placement_mgr
    p = eng.api.placement
    assert p.primary_slots == 6 and p.num_slots == 12
    owner = p.slot_owner()
    ew2_slots = [s for s in range(p.num_slots) if owner[s] == 2]
    assert len(ew2_slots) == 4
    slot_expert = np.full((p.num_slots,), -1, np.int32)
    slot_expert[:4] = np.arange(4)                  # identity primaries
    for ex, s in enumerate(ew2_slots):              # all replicas on EW2
        slot_expert[s] = ex
    plan = mgr.adopt(slot_expert, reason="dual protect ew0+ew1")
    eng.install_plan(plan)
    cand = plan.candidates()
    assert all(cand[e, 1] >= 0 and owner[cand[e, 1]] == 2 for e in range(4))
    return eng


def test_ew_dies_while_other_ew_mid_provision():
    """EW0 fails; while its replacement is still provisioning (T_w), EW1
    fails too. With both EWs' experts replica-covered on EW2, every token
    matches the failure-free run and nothing is lost."""
    ref_a = _dual_protected_engine().generate("a", PROMPT_A, 16)
    ref_b = _dual_protected_engine().generate("b", PROMPT_B, 16)

    eng = _dual_protected_engine()
    orch = Orchestrator(eng, worker_init_time=1.0, weight_push_time=0.2)
    eng.submit("a", PROMPT_A, 16)
    eng.submit("b", PROMPT_B, 16)
    for _ in range(4):
        eng.step()
    orch.inject_failure("ew", 0, now=10.0)
    fired = orch.tick(10.0 + orch.detection_latency() + 1e-6)
    assert any(e.kind == "detected" for e in fired)
    assert eng.failed_ews == {0}
    for _ in range(3):
        eng.step()
    # EW0's replacement is mid-provision (ready ~11.03+T_w) when EW1 dies
    orch.inject_failure("ew", 1, now=10.5)
    fired = orch.tick(10.5 + orch.detection_latency() + 1e-6)
    assert any(e.kind == "detected" for e in fired)
    assert eng.failed_ews == {0, 1}
    while eng.active_requests():
        eng.step()
    assert eng.requests["a"].tokens == ref_a
    assert eng.requests["b"].tokens == ref_b
    # both replacements eventually provision; re-pointing while EW1 was
    # still down must have pinned its failover replicas (dead_ews contract)
    orch.tick(11.2)
    assert eng.failed_ews == {1}
    from repro.core import selfheal
    assert selfheal.experts_without_healthy_replica(
        eng.route_state, eng.api.placement).size == 0
    orch.tick(11.8)
    assert eng.failed_ews == set()
    assert orch.outstanding == 0


def test_aw_and_ew_die_in_same_detection_window():
    """AW0 and EW0 fail inside one detection window: per-request restoration
    (checkpointed KV onto AW1) composes with the shadow failover (EW0's
    experts re-pointed to replicas) — bit-identical, nothing lost."""
    ref_a = make_engine().generate("a", PROMPT_A, 14)
    ref_b = make_engine().generate("b", PROMPT_B, 14)

    eng = make_engine()
    orch = Orchestrator(eng, worker_init_time=1.0)
    eng.submit("a", PROMPT_A, 14)     # -> AW0 (least loaded, lowest id)
    eng.submit("b", PROMPT_B, 14)     # -> AW1
    for _ in range(4):
        eng.step()
    assert eng.requests["a"].aw == 0 and eng.requests["b"].aw == 1
    orch.inject_failure("aw", 0, now=5.0)
    orch.inject_failure("ew", 0, now=5.0)
    fired = orch.tick(5.0 + orch.detection_latency() + 1e-6)
    assert sorted(e.kind for e in fired) == ["detected", "detected"]
    assert eng.failed_aws == {0} and eng.failed_ews == {0}
    assert eng.requests["a"].aw == 1          # restored onto the healthy AW
    while eng.active_requests():
        eng.step()
    assert eng.requests["a"].tokens == ref_a
    assert eng.requests["b"].tokens == ref_b
    assert eng.store.stats.restores == 1
    # background provisioning restores the full pool
    orch.tick(7.0)
    assert eng.failed_aws == set() and eng.failed_ews == set()
    assert orch.outstanding == 0


def test_compound_failure_during_chunked_prefill():
    """AW dies mid-chunked-prefill AND an EW dies in the same window: the
    prefill stream resumes from its committed cursor on the healthy AW
    while expert traffic rides the shadows — the finished output equals the
    failure-free run's."""
    long_prompt = np.arange(1, 33, dtype=np.int32)
    kw = dict(chunk_token_budget=8, prefill_bucket=16, max_seq=64)
    ref = make_engine(**kw).generate("r", long_prompt, 10)

    eng = make_engine(**kw)
    orch = Orchestrator(eng, worker_init_time=1.0)
    eng.submit("r", long_prompt, 10)
    eng.step()                                  # a budgeted chunk lands
    r = eng.requests["r"]
    assert r.prefilling and r.prefill_cursor > 0
    aw = r.aw
    orch.inject_failure("aw", aw, now=3.0)
    orch.inject_failure("ew", 0, now=3.0)
    orch.tick(3.0 + orch.detection_latency() + 1e-6)
    while not eng.requests["r"].done:
        eng.step()
    assert eng.requests["r"].tokens == ref
    assert eng.chunked.stats.resumed == 1       # stream resumed, not redone
