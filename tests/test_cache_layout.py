"""CacheLayout (generic per-request segment extract/restore) roundtrips for
every model family's cache structure."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from conftest import make_batch, reduced
from repro.models import get_model
from repro.serving.kvcache import CacheLayout
from repro.serving.workers import AttentionWorker, ClusterSlotView


@pytest.mark.parametrize("arch", ["qwen2_1_5b", "gemma2_2b", "mixtral_8x7b",
                                  "zamba2_7b", "xlstm_350m",
                                  "whisper_small"])
def test_request_state_roundtrip(arch, key):
    cfg = reduced(arch)
    api = get_model(cfg, num_aw=1, num_ew=2)
    layout = CacheLayout(api.init_cache)
    params = api.init_params(key)
    rs = api.init_route_state()
    batch = make_batch(cfg, 1, 8)
    _, req_cache = api.prefill(params, {k: v for k, v in batch.items()},
                               rs, max_seq=16)
    state = layout.request_state(req_cache, 0)

    # write into slot 2 of a 4-slot cache and read back
    glob = api.init_cache(4, 16)
    glob = layout.write_request_state(glob, 2, state)
    back = layout.request_state(glob, 2)
    for a, b in zip(state, back):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


@pytest.mark.parametrize("arch", ["qwen2_1_5b", "mixtral_8x7b"])
def test_token_segment_roundtrip_attention(arch, key):
    cfg = reduced(arch)
    api = get_model(cfg, num_aw=1, num_ew=2)
    layout = CacheLayout(api.init_cache)
    params = api.init_params(key)
    rs = api.init_route_state()
    batch = make_batch(cfg, 2, 8)
    _, cache = api.prefill(params, batch, rs, max_seq=16)
    # segment-by-segment copy of slot 0 into a fresh cache slot 1
    fresh = api.init_cache(2, 16)
    for t in range(8):
        seg = layout.token_segment(cache, 0, t)
        fresh = layout.write_token_segment(fresh, 1, t, seg)
    want = layout.request_state(cache, 0)
    got = layout.request_state(fresh, 1)
    for a, b, kind in zip(want, got, layout.leaf_kind):
        if kind.startswith("attn_"):
            np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


def test_attention_nodes_detected():
    cfg = reduced("whisper_small")
    api = get_model(cfg)
    layout = CacheLayout(api.init_cache)
    kinds = set(layout.leaf_kind)
    assert "attn_k" in kinds and "attn_pos" in kinds
    # cross-KV has no pos -> classified as state
    assert "state" in kinds


def test_segment_nbytes_matches_appendix_c():
    """Attention token segments have size C = 2*Hkv*head_dim*bytes per
    layer (paper App. C)."""
    cfg = reduced("qwen2_1_5b")
    api = get_model(cfg)
    layout = CacheLayout(api.init_cache)
    cache = api.init_cache(1, 8)
    seg = layout.token_segment(cache, 0, 0)
    attn_bytes = layout.segment_nbytes(seg, attn_only=True)
    # pos leaves add 4 bytes per layer-stack entry; subtract them
    pos_bytes = sum(np.asarray(s).nbytes
                    for s, k in zip(seg, layout.leaf_kind)
                    if k == "attn_pos")
    per_layer = 2 * cfg.num_kv_heads * cfg.head_dim_ * 4  # f32 here
    assert attn_bytes - pos_bytes == cfg.num_layers * per_layer


def test_slot_partitions_and_failure():
    from repro.core.checkpoint import CheckpointStore
    import jax.numpy as _jnp
    from repro.core.refe import RouteState
    store = CheckpointStore()
    aws = [AttentionWorker(a, a * 4, (a + 1) * 4, store) for a in range(2)]
    sm = ClusterSlotView(aws, 8)
    s0 = sm.alloc(0)
    s1 = sm.alloc(1)
    assert sm.aw_of(s0) == 0 and sm.aw_of(s1) == 1
    rs = RouteState(candidates=_jnp.zeros((0, 2), _jnp.int32),
                    ew_health=_jnp.ones((2,), bool),
                    aw_health=_jnp.ones((2,), bool),
                    slot_expert=_jnp.zeros((0,), _jnp.int32),
                    slot_owner=_jnp.zeros((0,), _jnp.int32),
                    split_slot=_jnp.zeros((0,), _jnp.int32))
    rs = aws[0].fail(rs)
    assert not bool(rs.aw_health[0])
    assert sm.free_count(0) == 0
    assert sm.free_count(1) == 3
    rs = aws[0].provision(rs, in_use=set())
    assert bool(rs.aw_health[0])
    assert sm.free_count(0) == 4
