import dataclasses
import os

os.environ.setdefault("JAX_PLATFORMS", "cpu")

import jax  # noqa: E402
import numpy as np  # noqa: E402
import pytest  # noqa: E402

from repro.configs import ASSIGNED_ARCHS, get_config  # noqa: E402


def reduced(name: str, cap_factor: float = 0.0):
    cfg = get_config(name).reduced()
    if cap_factor and cfg.moe.enabled:
        cfg = dataclasses.replace(
            cfg, moe=dataclasses.replace(cfg.moe,
                                         capacity_factor=cap_factor))
    return cfg


@pytest.fixture(scope="session")
def key():
    return jax.random.PRNGKey(0)


def all_arch_ids():
    return list(ASSIGNED_ARCHS) + ["mixtral_8x7b"]


def make_batch(cfg, b, s, rng=None, with_labels=False):
    rng = rng or np.random.default_rng(0)
    batch = {"tokens": rng.integers(0, cfg.vocab_size, (b, s)).astype(np.int32)}
    if with_labels:
        batch["labels"] = rng.integers(0, cfg.vocab_size,
                                       (b, s)).astype(np.int32)
    if cfg.is_encdec:
        batch["frames"] = rng.normal(size=(b, cfg.encoder_seq,
                                           cfg.d_model)).astype(np.float32)
    return batch


# --------------------------------------------------------------------------
# postmortem on test failure (serving/flightrec.py): any engine built
# during a failing test still holds its flight recorder — dump the most
# recent ones as bundles so CI can upload the incident, not just the
# traceback. Best-effort: a broken engine must never mask the failure.
# --------------------------------------------------------------------------

FLIGHTREC_DIR = os.environ.get("FLIGHTREC_DIR", "artifacts/flightrec")


@pytest.hookimpl(hookwrapper=True)
def pytest_runtest_makereport(item, call):
    outcome = yield
    report = outcome.get_result()
    if report.when != "call" or not report.failed:
        return
    try:
        from repro.serving import flightrec
        paths = flightrec.dump_live_recorders(FLIGHTREC_DIR, item.nodeid)
        if paths:
            report.sections.append(
                ("flight recorder", "postmortem bundles:\n" +
                 "\n".join(f"  {p}" for p in paths)))
    except Exception:
        pass
