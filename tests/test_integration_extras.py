"""Extra integration coverage: cascading failures, weight checkpoint I/O,
repeated failover cycles, MoE decode under degraded expert capacity."""
import dataclasses

import jax
import numpy as np
import pytest

from conftest import reduced
from repro.serving.engine import EngineConfig, InferenceEngine
from repro.training.checkpoint_io import load_params, save_params

PROMPT = np.arange(1, 9, dtype=np.int32)


def make_engine(num_aw=2, num_ew=2, seed=7, **kw):
    cfg = reduced("mixtral_8x7b", cap_factor=4.0)
    ecfg = EngineConfig(max_batch=8, max_seq=64, num_aw=num_aw,
                        num_ew=num_ew, **kw)
    return InferenceEngine(cfg, ecfg, jax.random.PRNGKey(seed))


def test_cascading_ew_then_aw_failure_exact():
    """Fail an EW, then the AW holding the request: both self-healing paths
    compose and the stream stays exact."""
    ref = make_engine().generate("r", PROMPT, 16)
    eng = make_engine()
    eng.submit("r", PROMPT, 16)
    for _ in range(3):
        eng.step()
    eng.fail_ew(0)          # shadow failover
    for _ in range(3):
        eng.step()
    eng.fail_aw(0)          # per-request restore onto AW1
    assert eng.recover_aw_requests() == ["r"]
    while not eng.requests["r"].done:
        eng.step()
    assert eng.requests["r"].tokens == ref


def test_failover_then_provision_then_fail_again():
    """Provision the EW back, re-point shadows, and survive failing the
    OTHER EW — the full §5.4 lifecycle."""
    ref = make_engine().generate("r", PROMPT, 16)
    eng = make_engine()
    eng.submit("r", PROMPT, 16)
    for _ in range(3):
        eng.step()
    eng.fail_ew(0)
    for _ in range(3):
        eng.step()
    eng.provision_ew(0, repoint_protect=1)   # now EW1's experts shadowed
    for _ in range(3):
        eng.step()
    eng.fail_ew(1)
    while not eng.requests["r"].done:
        eng.step()
    assert eng.requests["r"].tokens == ref


def test_aw_failure_with_no_spare_capacity_waits():
    """If no healthy AW has a free slot, recovery defers (until
    provisioning) instead of crashing."""
    eng = make_engine(num_aw=2)
    # fill AW1's slots completely
    for i in range(4):
        eng.submit(f"f{i}", PROMPT + i, 30)
    eng.submit("victim", PROMPT, 30)   # lands on AW0
    victim_aw = eng.requests["victim"].aw
    eng.fail_aw(victim_aw)
    recovered = eng.recover_aw_requests()
    others = [r for r in eng.requests.values() if r.aw != victim_aw]
    if all(eng.slots.free_count(a) == 0
           for a in range(2) if a != victim_aw):
        assert "victim" not in recovered
    # the rest of the pipeline keeps decoding
    out = eng.step()
    assert any(r.rid in out for r in others)


def test_weight_checkpoint_roundtrip(tmp_path, key):
    cfg = reduced("qwen2_1_5b")
    from repro.models import get_model
    api = get_model(cfg)
    params = api.init_params(key)
    path = str(tmp_path / "ckpt.npz")
    save_params(path, params, step=42)
    like = jax.tree_util.tree_map(lambda a: a, params)
    loaded, step = load_params(path, like)
    assert step == 42
    for a, b in zip(jax.tree_util.tree_leaves(params),
                    jax.tree_util.tree_leaves(loaded)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
    # loaded params produce identical logits
    rs = api.init_route_state()
    batch = {"tokens": np.arange(8, dtype=np.int32)[None]}
    l0, _ = api.forward_train(params, batch, rs)
    l1, _ = api.forward_train(loaded, batch, rs)
    np.testing.assert_array_equal(np.asarray(l0), np.asarray(l1))


def test_moe_decode_survives_total_expert_loss_on_one_layer():
    """Kill BOTH EWs' primaries for half the experts (no shadows for EW1):
    router renormalizes over reachable experts, decode continues."""
    eng = make_engine()
    eng.submit("r", PROMPT, 10)
    eng.fail_ew(1)   # experts of EW1 unreachable (shadows protect EW0 only)
    while not eng.requests["r"].done:
        eng.step()
    toks = eng.requests["r"].tokens
    assert len(toks) == 10
    assert all(0 <= t < eng.cfg.vocab_size for t in toks)
