"""Sharding rule unit tests (no big meshes — rule correctness only) plus a
1-device execution of a fully-sharded step."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest
from jax.sharding import PartitionSpec as P

from conftest import reduced
from repro.configs import get_config
from repro.launch.mesh import make_debug_mesh
from repro.launch.sharding import Sharder, ShardingPolicy
from repro.models import get_model
from repro.roofline.analysis import collective_bytes, model_flops
from repro.roofline.hlo_parse import analyze_hlo, parse_hlo


class FakeMesh:
    """Just enough mesh for Sharder rule checks."""
    def __init__(self, shape):
        self.shape = shape
        self.axis_names = tuple(shape)

    # NamedSharding construction is bypassed in these tests
    def __repr__(self):
        return f"FakeMesh({self.shape})"


def specs_for(cfg, mesh_shape, policy=ShardingPolicy()):
    sh = Sharder.__new__(Sharder)
    sh.cfg = cfg
    sh.mesh = FakeMesh(mesh_shape)
    sh.policy = policy
    dp = tuple(a for a in mesh_shape if a in ("pod", "data"))
    sh.dp = dp[0] if len(dp) == 1 else dp
    sh.mp = "model"
    sh.mp_size = mesh_shape["model"]
    sh.dp_size = int(np.prod([mesh_shape[a] for a in dp]))
    sh.data_size = mesh_shape["data"]
    return sh


def test_param_rules_dense():
    cfg = get_config("qwen2_1_5b")
    sh = specs_for(cfg, {"data": 16, "model": 16})
    assert sh.param_spec("blocks/0/attn/wq", (14, 1536, 1536)) == \
        P(None, None, "model")
    assert sh.param_spec("blocks/0/attn/wo", (14, 1536, 1536)) == \
        P(None, "model", None)
    assert sh.param_spec("blocks/0/mlp/w_up", (14, 1536, 8960)) == \
        P(None, None, "model")
    assert sh.param_spec("embed", (151936, 1536)) == P("model", None)
    assert sh.param_spec("blocks/0/ln1/scale", (1536,)) == P(None)


def test_param_rules_moe_and_divisibility_guard():
    cfg = get_config("kimi_k2_1t_a32b")
    sh = specs_for(cfg, {"data": 16, "model": 16},
                   ShardingPolicy(expert_ff_over_data=True))
    assert sh.param_spec("blocks/0/moe/experts/wu", (60, 384, 7168, 2048)) \
        == P(None, "model", None, "data")
    assert sh.param_spec("blocks/0/moe/experts/wd", (60, 384, 2048, 7168)) \
        == P(None, "model", "data", None)
    # 26 shadow slots don't divide 16 -> expert axis replicated
    assert sh.param_spec("blocks/0/moe/shadow/wu", (60, 26, 7168, 2048)) \
        == P(None, None, None, "data")
    # 32 slots divide -> sharded
    assert sh.param_spec("blocks/0/moe/shadow/wu", (60, 32, 7168, 2048)) \
        == P(None, "model", None, "data")


def test_cache_rules():
    cfg = get_config("qwen2_1_5b")
    sh = specs_for(cfg, {"data": 16, "model": 16})
    # Hkv=2 doesn't divide 16 -> fall back to sequence sharding
    assert sh.cache_spec("attn_k", (14, 128, 32768, 2, 128), 1) == \
        P(None, "data", "model", None, None)
    # Hkv=32 divides -> heads sharded
    assert sh.cache_spec("attn_k", (14, 128, 32768, 32, 112), 1) == \
        P(None, "data", None, "model", None)
    # batch=1 (long_500k): batch unsharded, seq over model
    assert sh.cache_spec("attn_k", (14, 1, 524288, 2, 128), 1) == \
        P(None, None, "model", None, None)


def test_batch_rules_multi_pod():
    cfg = get_config("qwen2_1_5b")
    sh = specs_for(cfg, {"pod": 2, "data": 16, "model": 16})
    assert sh.batch_spec((256, 4096)) == P(("pod", "data"), None)
    # batch 32 doesn't divide 32? it does (pod*data=32): sharded
    assert sh.batch_spec((32, 32768)) == P(("pod", "data"), None)
    # batch 1: replicated
    assert sh.batch_spec((1, 524288)) == P(None, None)


def test_sharded_decode_runs_on_one_device(key):
    """End-to-end: jit with explicit shardings on a 1x1 mesh executes."""
    cfg = reduced("mixtral_8x7b", cap_factor=4.0)
    mesh = make_debug_mesh((1, 1), ("data", "model"))
    api = get_model(cfg, num_aw=1, num_ew=1)
    sharder = Sharder(cfg, mesh)
    params = api.init_params(key)
    rs = api.init_route_state()
    cache = api.init_cache(2, 16)
    from repro.serving.kvcache import CacheLayout
    layout = CacheLayout(api.init_cache)
    with mesh:
        fn = jax.jit(
            api.decode,
            in_shardings=(sharder.shard_params(params),
                          sharder.named(P()), sharder.named(P()),
                          sharder.shard_cache(layout, cache),
                          sharder.replicated(rs)))
        logits, cache2 = fn(params, jnp.zeros((2,), jnp.int32),
                            jnp.full((2,), 3, jnp.int32), cache, rs)
    assert logits.shape == (2, cfg.vocab_size)
    assert not bool(jnp.isnan(logits).any())


def test_hlo_parser_loop_multiplicity():
    def f(x, w):
        def body(c, wi):
            return jnp.tanh(c @ wi), None
        y, _ = jax.lax.scan(body, x, w)
        return y

    xs = jax.ShapeDtypeStruct((8, 64), jnp.float32)
    ws = jax.ShapeDtypeStruct((7, 64, 64), jnp.float32)
    compiled = jax.jit(f).lower(xs, ws).compile()
    c = analyze_hlo(compiled.as_text())
    assert c.flops == 7 * 2 * 8 * 64 * 64


def test_model_flops_moe_uses_active_params():
    from repro.configs.base import SHAPES
    dense = get_config("qwen2_1_5b")
    moe = get_config("mixtral_8x7b")
    sh = SHAPES["decode_32k"]
    assert model_flops(moe, sh) < 6 * moe.param_count * sh.global_batch
    assert model_flops(dense, sh) == 2.0 * dense.param_count * \
        sh.global_batch
