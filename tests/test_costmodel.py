"""Recovery cost model (Eq. 1-4) + failover simulator: reproduce the paper's
quantitative claims (ratios are the scale-free reproduction targets)."""
import numpy as np
import pytest

from repro.core import costmodel as cm
from repro.core.events import (SimConfig, checkpoint_scheme_throughput,
                               failover_summary, link_trace,
                               preemption_summary,
                               simulate_megascale_failure,
                               simulate_preemption_recompute,
                               simulate_preemption_restore,
                               simulate_tarragon_aw_failure,
                               simulate_tarragon_ew_failure)


def test_eq1_grows_with_failure_point():
    p = cm.MEGASCALE_PROFILE
    s1 = cm.stall_monolithic(p, 32, 16, 8)
    s2 = cm.stall_monolithic(p, 32, 16, 64)
    s3 = cm.stall_monolithic(p, 32, 16, 512)
    assert s1 < s2 < s3
    # linear in i: slope = L * t_dec
    assert np.isclose((s3 - s2) / (512 - 64), 32 * p.t_dec)


def test_eq2_ew_stall_constant_in_i():
    p = cm.MEGASCALE_PROFILE
    assert cm.stall_decoupled_ew(p, 32, 1, 1) == \
        cm.stall_decoupled_ew(p, 32, 31, 4096)


def test_decoding_failures_dominate_prefill():
    """Paper §2.2.2 obs (2): at 64 decoded tokens, decode recovery cost
    already exceeds a 128-token-prompt prefill failure by ~19x (replay
    terms, excluding the common T_w)."""
    p = cm.MEGASCALE_PROFILE
    L = 32
    decode_replay = ((64 - 1) * L + L // 2) * p.t_dec
    prefill_replay = L * p.t_pre * (128 / 128)  # one prompt pass
    assert decode_replay / prefill_replay > 15


def test_tarragon_stall_nearly_flat_in_failure_point():
    p, t = cm.MEGASCALE_PROFILE, cm.TarragonProfile()
    s_early = cm.stall_tarragon_aw(p, t, 32, 16, 8, tokens_to_restore=18)
    s_late = cm.stall_tarragon_aw(p, t, 32, 16, 4096, tokens_to_restore=4106)
    assert s_late < 2 * s_early  # restoration is ~constant, not linear


def test_fig9_headline_ratios():
    """~64 s baseline stall; 0.3-0.4 s Tarragon stalls; 160-213x range."""
    s = failover_summary(SimConfig())
    assert 55 <= s["megascale_stall_s"] <= 75
    assert 0.25 <= s["tarragon_aw_stall_s"] <= 0.50
    assert 0.20 <= s["tarragon_ew_stall_s"] <= 0.40
    assert 120 <= s["aw_improvement_x"] <= 260
    assert 150 <= s["ew_improvement_x"] <= 320


def test_preemption_restore_beats_recompute():
    """Planned eviction on the recovery substrate: the victim's overhead
    beyond the slot loan is the per-request restore copy, an order of
    magnitude below discard-and-recompute's re-prefill + replay."""
    s = preemption_summary(SimConfig(), wait=1.0)
    assert s["restore_overhead_s"] < s["recompute_overhead_s"]
    assert s["overhead_improvement_x"] > 5
    # only the victim stalls; the pool keeps emitting
    tl = simulate_preemption_restore(SimConfig(duration=30.0,
                                               fail_time=10.0))
    during = tl.throughput[(tl.t >= 10.0) & (tl.t < 10.0 + tl.stall)]
    assert during.min() > 0
    # early-eviction edge: replay time never goes negative
    early = simulate_preemption_recompute(SimConfig(), t_evict=0.01)
    assert early.stall >= 1.0        # >= the slot loan


def test_timeline_shapes():
    c = SimConfig(duration=30.0, fail_time=10.0)
    for sim in (simulate_megascale_failure, simulate_tarragon_aw_failure,
                simulate_tarragon_ew_failure):
        tl = sim(c)
        assert tl.t.shape == tl.throughput.shape
        assert tl.stall > 0
        # throughput drops at failure
        before = tl.throughput[tl.t < c.fail_time].mean()
        at = tl.throughput[(tl.t >= c.fail_time) &
                           (tl.t < c.fail_time + tl.stall)].mean()
        assert at < before


def test_appendix_c_checkpoint_traffic_ratio():
    """Mixtral-8x7B: KV segment traffic ~12.5% of expert traffic."""
    r = cm.checkpoint_traffic_ratio(d_model=4096, n_heads=32, n_kv_heads=8,
                                    top_k=2)
    assert np.isclose(r, 0.125)


def test_checkpoint_schemes_ranking():
    """§7.4: incremental ~= none; pause-ckpt-resume(8) >= 2x worse."""
    c = SimConfig()
    none = checkpoint_scheme_throughput(c, "none")
    inc = checkpoint_scheme_throughput(c, "incremental")
    pause = checkpoint_scheme_throughput(c, "pause", interval_tokens=8)
    assert inc / none > 0.97            # <3% overhead claim
    assert none / pause >= 1.8          # paper: 2.15x at interval=8


def test_link_trace_checkpoint_fits_idle_gap():
    """Fig. 8: KV segments ride the attention-compute idle gaps."""
    events, info = link_trace(SimConfig())
    assert info["ckpt_fits_gap"]
    kinds = {k for _, _, k in events}
    assert {"idle", "ckpt", "dispatch", "gather"} <= kinds


def test_shadow_memory_budget():
    """§5.3: shadow bank is a small fraction of expert memory (one EW's
    worth + rounding)."""
    from repro.core import ert as ert_lib
    from repro.core.shadow import shadow_memory_bytes
    p = ert_lib.default_placement(384, 16)   # kimi-k2 geometry
    shadow = shadow_memory_bytes(p, 7168, 2048)
    primary = 384 * 3 * 7168 * 2048 * 2
    assert shadow / primary < 0.12
