"""Forensics plane (serving/flightrec.py + launch/replay.py).

The bar matches every other plane in this repo: the FlightRecorder may
only *observe* — recorder+watchdogs on/off is bit-identical with zero new
jit traces — and what it observes must be sufficient: a bundle dumped
from an AW-failure + preemption incident replays through
``launch/replay.py`` with token-identical outputs, in exact mode AND with
the controller's decisions replayed as a script. On top: ring-capacity
semantics (bounded memory, counted drops), bundle schema round-trip, the
health watchdogs (a seeded page leak trips within the window, a clean run
stays quiet, corrupted allocator state trips the invariant probe, a stall
regression vs the baseline window trips), autodump-on-detection, and the
``events.dropped`` counter satellite."""
import dataclasses
import json

import jax
import numpy as np

from conftest import reduced
from repro.core.costmodel import TarragonProfile
from repro.core.orchestrator import Orchestrator, WorkerEvent
from repro.data.workloads import make_workload
from repro.launch.replay import (BundleError, load_bundle,
                                 rebuild_engine_config,
                                 rebuild_model_config, replay_bundle)
from repro.serving import flightrec
from repro.serving.engine import EngineConfig, InferenceEngine
from repro.serving.scheduler import FailurePlan, run_serving

STEP = 0.02
PF_TOK = 0.002
_RUNS = {}


def make_engine(**kw):
    cfg = reduced("mixtral_8x7b", cap_factor=4.0)
    defaults = dict(max_batch=8, max_seq=96, num_aw=2, num_ew=2)
    defaults.update(kw)
    return InferenceEngine(cfg, EngineConfig(**defaults),
                          jax.random.PRNGKey(1))


def traces(eng):
    return eng._decode._cache_size() + eng.decode_plane.segment_traces()


def _workload():
    slo = make_workload("mixed_slo", rate_rps=3.0, duration=2.0, seed=7,
                        max_new=40, interactive_deadline=0.3,
                        batch_wave=8, batch_every=3.0)
    return sorted(slo, key=lambda r: (r.arrival, r.request_id))


def scenario(recording: bool):
    """One AW-failure + preemption incident (cached per on/off): mixed-SLO
    load saturates the slots, the failure at t=0.4 forces checkpoint
    restores, interactive heads preempt batch victims — the exact
    incident shape the acceptance criteria name."""
    if recording in _RUNS:
        return _RUNS[recording]
    cfg = reduced("mixtral_8x7b", cap_factor=4.0)
    ecfg = EngineConfig(max_batch=8, max_seq=96, num_aw=2, num_ew=2,
                        chunk_token_budget=16, preempt=True,
                        telemetry=True, stall_threshold=0.1,
                        flight_recorder=recording, watchdogs=recording,
                        flight_capacity=2048)
    eng = InferenceEngine(cfg, ecfg, jax.random.PRNGKey(1))
    orch = Orchestrator(eng, profile=TarragonProfile(detect=0.05,
                                                     detect_retries=2),
                        worker_init_time=0.5)
    m = run_serving(eng, _workload(), duration=60.0, orchestrator=orch,
                    failures=[FailurePlan(0.4, "aw", 0)],
                    step_time=STEP, prefill_token_time=PF_TOK)
    _RUNS[recording] = (eng, orch, m)
    return _RUNS[recording]


# --------------------------------------------------------------------------
# ring-capacity semantics: bounded memory, counted drops, newest kept
# --------------------------------------------------------------------------

def test_ring_capacity_drops_oldest_and_counts():
    eng = make_engine(flight_capacity=16, telemetry=True)
    fr = eng.flightrec
    for i in range(50):
        eng.bus.publish(WorkerEvent(float(i), "synthetic", f"w{i}"))
    fr.tick(50.0)
    assert len(fr.records) == 16                    # bounded
    assert fr.records_total >= 50
    assert fr.records_dropped == fr.records_total - 16
    # oldest dropped, newest survive (tick appends a fingerprint after
    # the drain, so the newest synthetic sits just before it)
    synth = [r["who"] for r in fr.records if r["kind"] == "synthetic"]
    assert synth[-1] == "w49" and "w0" not in synth
    # drop counters surface through the registry
    eng.telemetry.sync()
    c = eng.telemetry.registry.counters
    assert c["flightrec.records_dropped"] == fr.records_dropped
    # and a dump refuses nothing but MARKS the truncation
    b = fr.dump(reason="capacity test")
    assert b["truncated"]["records"] == fr.records_dropped


# --------------------------------------------------------------------------
# bundle schema round-trip
# --------------------------------------------------------------------------

def test_bundle_schema_roundtrip(tmp_path):
    eng, orch, m = scenario(True)
    path = str(tmp_path / "incident.postmortem.json")
    eng.flightrec.dump(path, reason="roundtrip")
    b = load_bundle(path)
    assert b["schema"] == flightrec.SCHEMA
    for k in ("reason", "clock", "config", "loops", "orchestrator",
              "injections", "records", "submissions", "outputs",
              "request_states", "workers", "open_spans", "stalls",
              "truncated", "health"):
        assert k in b, k
    # the config hash survives the JSON round-trip (tuples -> lists)
    assert flightrec.hash_config_dicts(
        b["config"]["model"], b["config"]["engine"]) == b["config"]["hash"]
    # and the configs rebuild to the live dataclasses exactly
    assert rebuild_model_config(b["config"]["model"]) == eng.cfg
    ecfg2 = rebuild_engine_config(b["config"]["engine"], "exact")
    assert ecfg2 == dataclasses.replace(eng.ecfg, flight_autodump="",
                                        trace_export_path="")
    # every finished request's recorded output matches the run's
    assert b["outputs"] == {rid: toks for rid, toks in m.outputs.items()}
    # the incident is actually in the record: failure, restore, preemption
    kinds = {r["kind"] for r in b["records"]}
    assert {"fail_aw", "detected", "restore", "preempted",
            "fingerprint", "submit"} <= kinds, kinds


# --------------------------------------------------------------------------
# deterministic incident replay (the tentpole claim)
# --------------------------------------------------------------------------

def test_replay_bit_identity_on_failure_preemption_incident(tmp_path):
    """A bundle dumped from the AW-failure + preemption incident replays
    against a fresh engine with token-identical outputs."""
    eng, orch, m = scenario(True)
    assert eng.gateway.stats.preemptions >= 1      # non-vacuous incident
    assert any(e.kind == "detected" for e in orch.events)
    path = str(tmp_path / "incident.postmortem.json")
    eng.flightrec.dump(path, reason="replay test")
    report = replay_bundle(load_bundle(path))
    assert report["config_hash_ok"]
    assert report["mismatched"] == [] and report["missing"] == []
    assert report["matched"] == len(m.outputs) > 0
    assert report["ok"]


def test_replay_script_mode_controller_incident(tmp_path):
    """The stronger forensic claim: a controller-driven incident replays
    bit-identically with the controller OFF and its recorded decisions
    applied as a script (PR 9's replay theorem, now bundle-powered)."""
    wl = make_workload("mixed_slo", rate_rps=3.0, duration=3.0, seed=7,
                       interactive_deadline=0.3)
    wl = [dataclasses.replace(w, prompt_len=min(w.prompt_len, 16),
                              max_new_tokens=min(w.max_new_tokens, 8))
          for w in wl]
    eng = make_engine(max_seq=64, max_ew=4, chunk_token_budget=32,
                      prefill_token_cap=256, controller="on")
    orch = Orchestrator(eng, worker_init_time=0.4, weight_push_time=0.2)
    m = run_serving(eng, wl, 60.0, orchestrator=orch, step_time=STEP,
                    prefill_token_time=PF_TOK)
    assert eng.controller.decisions            # the loop actually closed
    path = str(tmp_path / "ctl.postmortem.json")
    eng.flightrec.dump(path, reason="controller incident")
    report = replay_bundle(load_bundle(path), mode="script")
    assert report["ok"], report
    assert report["matched"] == len(m.outputs) > 0


def test_replay_refuses_unreplayable_bundles(tmp_path):
    eng, _, _ = scenario(True)
    b = eng.flightrec.dump(reason="refusal test")
    wall = json.loads(json.dumps(b))
    wall["loops"][0]["step_time"] = None
    try:
        replay_bundle(wall)
        assert False, "wall-clock bundle must be refused"
    except BundleError as e:
        assert "wall-clock" in str(e)
    trunc = json.loads(json.dumps(b))
    trunc["truncated"]["submissions"] = 3
    try:
        replay_bundle(trunc)
        assert False, "truncated bundle must be refused"
    except BundleError as e:
        assert "truncated" in str(e)


# --------------------------------------------------------------------------
# recorder+watchdogs on/off: bit-identical, zero new jit traces
# --------------------------------------------------------------------------

def test_recorder_on_off_bit_identical():
    _, _, m_on = scenario(True)
    _, _, m_off = scenario(False)
    assert set(m_on.outputs) == set(m_off.outputs)
    for rid, toks in m_off.outputs.items():
        assert m_on.outputs[rid] == toks, rid
    assert m_on.finished == m_off.finished


def test_recorder_mints_zero_new_jit_traces():
    eng_on, _, _ = scenario(True)
    eng_off, _, _ = scenario(False)
    assert eng_on.flightrec is not None and eng_off.flightrec is None
    assert traces(eng_on) == traces(eng_off)


# --------------------------------------------------------------------------
# health watchdogs
# --------------------------------------------------------------------------

def test_clean_incident_run_no_watchdog_trips():
    """Failover churn (failure, restores, preemptions) must NOT read as
    degradation — the disturbance suppression exists exactly for this."""
    eng, _, _ = scenario(True)
    wd = eng.flightrec.watchdogs
    assert wd is not None and wd.intervals > 0
    assert wd.trips == [], wd.trips


def test_seeded_page_leak_trips_leak_watchdog():
    """One page allocated-and-orphaned per tick: the free-list watermark
    trends monotonically down and the leak detector trips within the
    window, while the twin run without the leak stays quiet."""
    def soak(leak: bool):
        eng = make_engine(kv_page_tokens=16, watchdogs=True,
                          wd_interval=0.1, wd_window=4, wd_leak_min_drop=3,
                          wd_settle=0.0)
        fr = eng.flightrec
        now = 0.0
        for i in range(40):
            if leak:
                assert eng.pages.alloc(i % eng.ecfg.num_aw) > 0
            fr.tick(now)
            now += 0.05
        return eng
    leaky = soak(True)
    wd = leaky.flightrec.watchdogs
    assert wd.trip_counts.get("leak", 0) >= 1, wd.trips
    trip = next(t for t in wd.trips if t["kind"] == "leak")
    assert trip["what"] == "pages"
    assert trip["watermarks"] == sorted(trip["watermarks"], reverse=True)
    # the orphaned pages are a leak, not corruption: the allocator oracle
    # stays green, so only the trend detector could have caught this
    leaky.pages.check()
    assert wd.trip_counts.get("invariant", 0) == 0
    clean = soak(False)
    assert clean.flightrec.watchdogs.trips == []


def test_invariant_probe_trips_on_corrupted_pool():
    eng = make_engine(kv_page_tokens=16, watchdogs=True,
                      wd_interval=0.1, wd_window=4, wd_settle=0.0)
    pid = eng.pages.alloc(0)
    eng.pages._free[0].append(pid)        # allocated AND free: corruption
    fr = eng.flightrec
    for i in range(5):
        fr.tick(i * 0.05)
    wd = fr.watchdogs
    assert wd.trip_counts.get("invariant", 0) == 1, wd.trips
    assert "allocated AND free" in wd.trips[0]["detail"]
    # trips once per resource, not once per interval
    for i in range(5, 10):
        fr.tick(i * 0.05)
    assert wd.trip_counts["invariant"] == 1


def test_stall_regression_trips_vs_baseline_window():
    """Windowed TBT p99 jumping far above the baseline window (with no
    disturbance to excuse it) trips the stall-regression detector."""
    eng = make_engine(telemetry=True, watchdogs=True, wd_interval=0.1,
                      wd_window=4, wd_stall_factor=2.0, wd_settle=0.0,
                      stall_threshold=0.1)
    wd = eng.flightrec.watchdogs
    h = eng.telemetry.registry.hist("tbt")
    now = 0.0
    # two healthy windows: the first sets the histogram cursor, the
    # second becomes the baseline (p99 ~ 0.02)
    for _ in range(3):
        for _ in range(20):
            h.observe(0.02)
        now += 0.11
        wd.tick(now)
    assert wd.baseline_p99.get("tbt") is not None
    assert wd.trips == []
    # then a regressed window: gaps 50x the baseline
    for _ in range(20):
        h.observe(1.0)
    now += 0.11
    wd.tick(now)
    assert wd.trip_counts.get("stall_regression", 0) == 1, wd.trips
    assert wd.trips[-1]["what"] == "tbt"


def test_watchdog_trips_emit_health_events():
    eng = make_engine(kv_page_tokens=16, telemetry=True, watchdogs=True,
                      wd_interval=0.1, wd_window=4, wd_leak_min_drop=3,
                      wd_settle=0.0)
    fr = eng.flightrec
    now = 0.0
    for i in range(40):
        eng.pages.alloc(i % eng.ecfg.num_aw)
        fr.tick(now)
        now += 0.05
    assert any(e.kind == "health_leak" for e in eng.bus.events)
    eng.telemetry.sync()
    c = eng.telemetry.registry.counters
    assert c["health.trips"] >= 1
    assert c["health.trips.leak"] >= 1


# --------------------------------------------------------------------------
# autodump on failure detection
# --------------------------------------------------------------------------

def test_autodump_on_failure_detection(tmp_path):
    path = str(tmp_path / "auto.postmortem.json")
    eng = make_engine(chunk_token_budget=16, flight_autodump=path)
    orch = Orchestrator(eng, profile=TarragonProfile(detect=0.05,
                                                     detect_retries=2),
                        worker_init_time=0.5)
    wl = _workload()[:6]
    run_serving(eng, wl, duration=60.0, orchestrator=orch,
                failures=[FailurePlan(0.3, "aw", 0)],
                step_time=STEP, prefill_token_time=PF_TOK)
    b = load_bundle(path)
    assert b["reason"].startswith("failure detected")
    # dumped at detection: the incident window is open, not done
    assert eng.flightrec.last_dump_path == path
    # a second detection must not overwrite the incident bundle
    assert eng.flightrec._autodumped


# --------------------------------------------------------------------------
# satellite: events.dropped counter (bus cap-drop visibility)
# --------------------------------------------------------------------------

def test_events_dropped_counter_surfaces_bus_cap_drops():
    eng = make_engine(telemetry=True)
    eng.bus.max_events = len(eng.bus.events) + 2
    for i in range(6):
        eng.bus.publish(WorkerEvent(0.0, "storm", f"w{i}"))
    assert eng.bus.dropped == 4
    eng.telemetry.sync()
    reg = eng.telemetry.registry
    assert reg.counters["events.dropped"] == 4
    assert "events_dropped_total 4" in reg.prometheus_text()
