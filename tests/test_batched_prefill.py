"""ContinuousBatchScheduler: length-bucketed batched prefill.

Acceptance: prefill of N waiting requests with similar lengths runs as
<= ceil(N / bucket_batch) jitted prefill calls (no per-request recompile),
and batching never changes the decoded tokens."""
import jax
import numpy as np
import pytest

from conftest import reduced
from repro.serving.engine import EngineConfig, InferenceEngine


def make_engine(**kw):
    cfg = reduced("mixtral_8x7b", cap_factor=4.0)
    ecfg = EngineConfig(max_batch=8, max_seq=64, num_aw=2, num_ew=2, **kw)
    return InferenceEngine(cfg, ecfg, jax.random.PRNGKey(0))


def prompts(lens, seed=11):
    rng = np.random.default_rng(seed)
    return [rng.integers(1, 200, size=(n,)).astype(np.int32) for n in lens]


def test_similar_lengths_share_one_prefill_call():
    eng = make_engine()
    assert eng.prefill_paddable
    lens = [6, 9, 12, 7, 15]                 # all within one 16-bucket
    for i, p in enumerate(prompts(lens)):
        eng.gateway.enqueue(f"r{i}", p, 4, now=0.0)
    installed = eng.scheduler.admit(0.0)
    assert len(installed) == 5
    st = eng.scheduler.stats
    assert st.calls == 1                     # ONE jitted call, not five
    assert st.requests == 5
    assert st.batch_sizes == [5]
    assert 0.0 < st.occupancy() <= 1.0
    while eng.active_requests():
        eng.step()
    assert all(len(eng.requests[f"r{i}"].tokens) == 4 for i in range(5))


def test_distinct_buckets_split_calls():
    eng = make_engine()
    lens = [5, 8, 20, 25]                    # 16-bucket and 32-bucket
    for i, p in enumerate(prompts(lens)):
        eng.gateway.enqueue(f"r{i}", p, 4, now=0.0)
    eng.scheduler.admit(0.0)
    assert eng.scheduler.stats.calls == 2
    assert sorted(eng.scheduler.stats.batch_sizes) == [2, 2]


def test_batched_prefill_tokens_match_sequential():
    """Batch composition must not change results: the same prompts admitted
    together vs one-by-one produce identical token streams."""
    lens = [6, 9, 12]
    ps = prompts(lens)

    eng_b = make_engine()
    for i, p in enumerate(ps):
        eng_b.gateway.enqueue(f"r{i}", p, 6, now=0.0)
    eng_b.scheduler.admit(0.0)
    assert eng_b.scheduler.stats.calls == 1
    while eng_b.active_requests():
        eng_b.step()

    eng_s = make_engine()
    for i, p in enumerate(ps):
        assert eng_s.submit(f"r{i}", p, 6)   # separate prefill each
    assert eng_s.scheduler.stats.calls == 3
    while eng_s.active_requests():
        eng_s.step()

    for i in range(3):
        assert eng_b.requests[f"r{i}"].tokens == \
            eng_s.requests[f"r{i}"].tokens


def test_max_new_one_completes_at_admission_exact_scheme():
    """A 1-token prompt uses the exact scheme (first token from prefill
    logits); max_new=1 must finish at admission with exactly one token."""
    eng = make_engine()
    assert eng.submit("r", np.asarray([5], np.int32), 1)
    r = eng.requests["r"]
    assert r.done and len(r.tokens) == 1
    assert eng.step() == {}            # nothing left to decode


def test_release_while_queued_for_recovery_cancels_cleanly():
    """Releasing a request that is waiting for recovery must drop its
    Gateway entry — a later admit tick must not resurrect it."""
    eng = make_engine()
    ps = prompts([7] * 8)
    for i in range(8):                       # saturate both AWs
        assert eng.submit(f"f{i}", ps[i], 30)
    for _ in range(2):
        eng.step()
    on0 = sorted(r.rid for r in eng.requests.values() if r.aw == 0)
    on1 = [r.rid for r in eng.requests.values() if r.aw == 1]
    eng.fail_aw(0)
    assert eng.recover_aw_requests() == []   # AW1 full: all stay queued
    assert eng.gateway.depth() == len(on0)
    eng.release_request(on0[0])
    assert eng.gateway.depth() == len(on0) - 1   # entry cancelled
    assert eng.scheduler.admit(0.0) == []    # still no capacity, no crash
    # freeing a healthy slot lets the next queued entry restore
    eng.release_request(on1[0])
    assert eng.scheduler.admit(0.0) == [on0[1]]
    assert not eng.requests[on0[1]].paused
    assert eng.step()                        # the fleet keeps decoding


def test_non_paddable_arch_groups_exact_lengths():
    """Recurrent-state caches must never see pad tokens: equal-length
    prompts still batch (exact scheme), unequal ones split."""
    cfg = reduced("xlstm_350m")
    ecfg = EngineConfig(max_batch=4, max_seq=40, num_aw=2, num_ew=1)
    eng = InferenceEngine(cfg, ecfg, jax.random.PRNGKey(5))
    assert not eng.prefill_paddable
    ps = prompts([8, 8, 5])
    for i, p in enumerate(ps):
        eng.gateway.enqueue(f"r{i}", p, 3, now=0.0)
    eng.scheduler.admit(0.0)
    assert eng.scheduler.stats.calls == 2    # {8,8} together, {5} alone
    assert sorted(eng.scheduler.stats.batch_sizes) == [1, 2]
    while eng.active_requests():
        eng.step()
    assert all(len(r.tokens) == 3 for r in eng.requests.values())
